//! Telemetry integration contract:
//!
//! * attaching a sink (null or recording) changes NO experiment result —
//!   the observed run is compared field-for-field against the untraced
//!   coordinator path, and traced scenario reports are byte-identical to
//!   plain ones;
//! * span streams are deterministic: identical across repeat runs and
//!   across sweep worker counts (`--jobs 1` vs `--jobs 2`);
//! * per-phase billed-cost attribution sums bit-exactly to the billed
//!   total on every catalog scenario;
//! * the Chrome trace-event export parses, carries events, and embeds
//!   the same metrics the report carries.

use elastibench::config::{ExperimentConfig, PlatformConfig, SutConfig};
use elastibench::coordinator::{run_experiment_observed, run_experiment_with, strategy_by_name};
use elastibench::report::scenario_report_to_json;
use elastibench::scenario::{
    catalog, catalog_entry, run_scenario, run_scenario_experiment,
    run_scenario_experiment_traced, run_scenario_traced, run_sweep, Scenario,
};
use elastibench::stats::Analyzer;
use elastibench::sut::{generate, Version};
use elastibench::telemetry::{chrome_trace_json, NullSink, SharedSink, TRACE_SCHEMA};
use elastibench::util::json::parse;
use std::cell::RefCell;
use std::rc::Rc;

fn small_workload() -> (SutConfig, PlatformConfig, ExperimentConfig) {
    let sut = SutConfig {
        benchmark_count: 12,
        true_changes: 3,
        faas_incompatible: 1,
        slow_setup: 1,
        ..SutConfig::default()
    };
    let platform = PlatformConfig::default();
    let exp = ExperimentConfig {
        calls_per_benchmark: 6,
        parallelism: 8,
        ..ExperimentConfig::default()
    };
    (sut, platform, exp)
}

/// Scale a catalog entry down to test time while keeping its platform
/// calibration (billing floors, pricing, keepalive) untouched — the
/// parts that matter for cost attribution.
fn scaled(mut sc: Scenario) -> Scenario {
    sc.sut.benchmark_count = sc.sut.benchmark_count.min(10);
    sc.sut.true_changes = sc.sut.true_changes.min(3);
    sc.sut.faas_incompatible = sc.sut.faas_incompatible.min(1);
    sc.sut.slow_setup = sc.sut.slow_setup.min(1);
    sc.exp.calls_per_benchmark = sc.exp.calls_per_benchmark.min(6);
    sc.exp.parallelism = sc.exp.parallelism.min(40);
    sc
}

#[test]
fn sinks_have_zero_result_impact() {
    let (sut, platform, exp) = small_workload();
    let suite = generate(&sut);
    let duet = strategy_by_name("duet").unwrap();
    let plain = run_experiment_with(
        &suite,
        &sut,
        &platform,
        &exp,
        (Version::V1, Version::V2),
        duet,
    );

    let null_sink: SharedSink = Rc::new(RefCell::new(NullSink));
    let (nulled, _) = run_experiment_observed(
        &suite,
        &sut,
        &platform,
        &exp,
        (Version::V1, Version::V2),
        duet,
        None,
        &null_sink,
    );
    let rec = elastibench::telemetry::RecordingSink::shared();
    let rec_sink: SharedSink = rec.clone();
    let (recorded, _) = run_experiment_observed(
        &suite,
        &sut,
        &platform,
        &exp,
        (Version::V1, Version::V2),
        duet,
        None,
        &rec_sink,
    );

    // Debug formatting round-trips every f64 exactly, so string equality
    // here is full-report equality.
    let want = format!("{plain:?}");
    assert_eq!(format!("{nulled:?}"), want, "NullSink changed the run");
    assert_eq!(format!("{recorded:?}"), want, "RecordingSink changed the run");
    assert!(
        !rec.borrow().spans.is_empty(),
        "recording run must actually capture spans"
    );
}

#[test]
fn traced_scenario_report_is_byte_identical_to_plain_run() {
    let sc = catalog_entry("quick-smoke").unwrap();
    let analyzer = Analyzer::native();
    let plain = run_scenario(&sc, &analyzer).unwrap();
    let (traced, spans) = run_scenario_traced(&sc, &analyzer).unwrap();
    assert!(!spans.is_empty());
    assert_eq!(
        scenario_report_to_json(&traced).to_string(),
        scenario_report_to_json(&plain).to_string(),
        "tracing must not perturb the exported report"
    );
}

#[test]
fn span_streams_are_identical_across_repeat_runs_and_threads() {
    let sc = catalog_entry("quick-smoke").unwrap();
    let analyzer = Analyzer::native();
    let (_, first) = run_scenario_experiment_traced(&sc, &analyzer).unwrap();
    let (_, second) = run_scenario_experiment_traced(&sc, &analyzer).unwrap();
    assert_eq!(first, second, "span stream must be deterministic");
    // Simulated-time determinism also holds on a fresh thread (sweep
    // workers run scenarios off the main thread).
    let sc2 = sc.clone();
    let threaded = std::thread::spawn(move || {
        run_scenario_experiment_traced(&sc2, &Analyzer::native())
            .unwrap()
            .1
    })
    .join()
    .unwrap();
    assert_eq!(first, threaded, "span stream must not depend on the thread");
}

#[test]
fn sweep_reports_with_telemetry_are_identical_across_jobs() {
    let base = catalog_entry("quick-smoke").unwrap();
    let mut other = base.clone();
    other.name = "quick-smoke-b".into();
    other.exp.seed += 1;
    let scenarios = vec![base, other];
    let one = run_sweep(&scenarios, 1, || Ok(Analyzer::native())).unwrap();
    let two = run_sweep(&scenarios, 2, || Ok(Analyzer::native())).unwrap();
    assert_eq!(one.len(), two.len());
    for (a, b) in one.iter().zip(&two) {
        assert!(a.telemetry.is_some(), "{}: sweep runs carry telemetry", a.scenario.name);
        assert_eq!(
            scenario_report_to_json(a).to_string(),
            scenario_report_to_json(b).to_string(),
            "{}: --jobs must not change the report",
            a.scenario.name
        );
    }
}

#[test]
fn phase_costs_sum_bit_exactly_on_every_catalog_scenario() {
    let analyzer = Analyzer::native();
    for sc in catalog() {
        let sc = scaled(sc);
        let pending = run_scenario_experiment(&sc, &analyzer).unwrap();
        let m = pending
            .telemetry
            .as_ref()
            .unwrap_or_else(|| panic!("{}: experiment runs carry telemetry", sc.name));
        let billed = pending.run.cost_usd;
        assert_eq!(
            m.phase_total_usd().to_bits(),
            billed.to_bits(),
            "{}: requests {} + cold {} + exec {} + rounding {} != billed {}",
            sc.name,
            m.cost_requests_usd,
            m.cost_cold_start_usd,
            m.cost_execution_usd,
            m.cost_rounding_usd,
            billed
        );
        assert_eq!(
            m.cold_starts, pending.run.platform.cold_starts,
            "{}: span-derived cold starts disagree with platform stats",
            sc.name
        );
        assert_eq!(
            m.invocations, pending.run.platform.invocations,
            "{}: span-derived invocations disagree with platform stats",
            sc.name
        );
    }
}

#[test]
fn chrome_trace_export_is_valid_and_embeds_matching_metrics() {
    let sc = catalog_entry("quick-smoke").unwrap();
    let (report, spans) = run_scenario_traced(&sc, &Analyzer::native()).unwrap();
    let metrics = report.telemetry.as_ref().expect("traced report has telemetry");
    let trace = chrome_trace_json(&report.scenario.name, &spans, metrics);
    let parsed = parse(&trace.to_string()).expect("trace must be valid JSON");

    assert_eq!(parsed.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
    let eb = parsed.get("elastibench").unwrap();
    assert_eq!(eb.get("schema").unwrap().as_str(), Some(TRACE_SCHEMA));
    assert_eq!(eb.get("scenario").unwrap().as_str(), Some("quick-smoke"));
    let embedded =
        elastibench::telemetry::run_metrics_from_json(eb.get("metrics").unwrap()).unwrap();
    assert_eq!(&embedded, metrics, "embedded metrics must match the report");

    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), spans.len(), "one trace event per span");
    for ev in events {
        assert!(ev.get("name").unwrap().as_str().is_some());
        let ph = ev.get("ph").unwrap().as_str().unwrap();
        assert!(ph == "X" || ph == "i", "unexpected phase {ph:?}");
        assert!(ev.get("ts").unwrap().as_f64().unwrap() >= 0.0);
        if ph == "X" {
            assert!(ev.get("dur").unwrap().as_f64().unwrap() >= 0.0);
        }
    }
    // Cold starts show up as complete events on instance tracks.
    assert!(
        events.iter().any(|e| e.get("name").unwrap().as_str() == Some("cold-start")),
        "trace must contain cold-start events"
    );
}
