//! End-to-end integration tests: full paper-scale runs and failure
//! injection through the public API.

use elastibench::config::{ExperimentConfig, PlatformConfig, SutConfig};
use elastibench::coordinator::{run_experiment, CallFailure};
use elastibench::exp::{aa, baseline, vm_original, Workbench};
use elastibench::stats::agreement;
use elastibench::sut::{generate, Version};

#[test]
fn paper_scale_headline_shape() {
    // The full 106-benchmark configuration must land in the paper's
    // ballpark: ~90 executed, 0 A/A changes, ≥85% agreement with the
    // original dataset, minutes vs hours, comparable cost.
    let wb = Workbench::native();
    let a = aa(&wb).expect("aa");
    assert_eq!(a.analysis.change_count(), 0, "A/A false positives");
    assert!(
        (85..=95).contains(&a.analysis.verdicts.len()),
        "A/A executed {}",
        a.analysis.verdicts.len()
    );

    let base = baseline(&wb).expect("baseline");
    let orig = vm_original(&wb).expect("vm");
    let rep = agreement(&base.analysis, &orig.analysis);
    assert!(
        rep.agreement_pct() >= 85.0,
        "agreement {}%",
        rep.agreement_pct()
    );
    assert!(
        base.report.wall_s < 20.0 * 60.0,
        "FaaS suite must finish within the function keep-window (paper ≤15 min): {}s",
        base.report.wall_s
    );
    assert!(
        orig.report.wall_s > 2.0 * 3600.0,
        "VM baseline takes hours: {}s",
        orig.report.wall_s
    );
    assert!(
        base.report.cost_usd < 2.0 * orig.report.cost_usd,
        "FaaS cost {} vs VM {}",
        base.report.cost_usd,
        orig.report.cost_usd
    );
}

#[test]
fn pathological_benchmark_reproduces_direction_flip() {
    // The BenchmarkAddMulti family must be detected with OPPOSITE
    // directions on the two platforms (paper §6.2.2).
    let wb = Workbench::native();
    let base = baseline(&wb).expect("baseline");
    let orig = vm_original(&wb).expect("vm");
    let mut flipped = 0;
    for b in &wb.suite.benchmarks {
        if !b.benchmark_changed() {
            continue;
        }
        let (Some(f), Some(v)) = (base.analysis.get(&b.name), orig.analysis.get(&b.name))
        else {
            continue;
        };
        if f.change.is_change() && v.change.is_change() && f.change != v.change {
            flipped += 1;
        }
    }
    assert!(flipped >= 2, "AddMulti direction flips: {flipped}");
}

#[test]
fn crash_injection_degrades_gracefully() {
    let sut = SutConfig {
        benchmark_count: 12,
        true_changes: 3,
        faas_incompatible: 1,
        slow_setup: 1,
        ..SutConfig::default()
    };
    let suite = generate(&sut);
    let platform = PlatformConfig {
        crash_probability: 0.15,
        ..PlatformConfig::default()
    };
    let exp = ExperimentConfig::default();
    let report = run_experiment(&suite, &sut, &platform, &exp, (Version::V1, Version::V2));
    assert!(report.failure_count(CallFailure::Crash) > 0, "crashes injected");
    // Despite crashes, healthy benchmarks still collect enough results.
    let healthy = suite
        .benchmarks
        .iter()
        .filter(|b| !b.writes_fs && b.setup_s < 6.0)
        .count();
    assert!(
        report.benchmarks_with_results(10) >= healthy,
        "healthy benchmarks analyzed: {} >= {healthy}",
        report.benchmarks_with_results(10)
    );
}

#[test]
fn throttled_platform_times_out_more() {
    let wb = Workbench::native();
    let exp2048 = ExperimentConfig::default();
    let exp1024 = ExperimentConfig {
        memory_mb: 1024,
        ..ExperimentConfig::default()
    };
    let full = run_experiment(&wb.suite, &wb.sut, &wb.platform, &exp2048, (Version::V1, Version::V2));
    let low = run_experiment(&wb.suite, &wb.sut, &wb.platform, &exp1024, (Version::V1, Version::V2));
    assert!(
        low.failure_count(CallFailure::BenchTimeout)
            > full.failure_count(CallFailure::BenchTimeout),
        "reduced vCPU share causes more timeouts (paper §6.2.4)"
    );
    assert!(low.benchmarks_with_results(10) < full.benchmarks_with_results(10));
}

#[test]
fn function_image_sizes_flow_into_cold_starts() {
    // Bigger image -> longer cold starts -> longer invoke phase at cold-
    // start-heavy parallelism.
    let slim = SutConfig {
        benchmark_count: 12,
        source_mb: 20.0,
        build_cache_mb: 60.0,
        tooling_mb: 40.0,
        ..SutConfig::default()
    };
    let fat = SutConfig {
        benchmark_count: 12,
        ..SutConfig::default()
    };
    let exp = ExperimentConfig {
        parallelism: 180,
        calls_per_benchmark: 15,
        ..ExperimentConfig::default()
    };
    let plat = PlatformConfig::default();
    let suite_slim = generate(&slim);
    let suite_fat = generate(&fat);
    let r_slim = run_experiment(&suite_slim, &slim, &plat, &exp, (Version::V1, Version::V2));
    let r_fat = run_experiment(&suite_fat, &fat, &plat, &exp, (Version::V1, Version::V2));
    assert!(
        r_fat.wall_s > r_slim.wall_s,
        "fat image {} vs slim {}",
        r_fat.wall_s,
        r_slim.wall_s
    );
}

#[test]
fn reproduction_report_contains_all_artifacts() {
    let wb = Workbench::with_sut(SutConfig {
        benchmark_count: 12,
        true_changes: 4,
        faas_incompatible: 2,
        slow_setup: 1,
        ..SutConfig::default()
    });
    let report = elastibench::exp::reproduce_all(&wb).expect("reproduce");
    for needle in ["Fig. 4", "Fig. 5", "Fig. 6", "Fig. 7", "Paper vs measured"] {
        assert!(report.contains(needle), "missing {needle}");
    }
}
