//! Reliability-strategy lab: the A/A + A/B accuracy scoreboard over
//! every [`ExecutionStrategy`] x provider calibration x noise regime.
//!
//! Each cell runs one A/A experiment (both lanes v1 — every change
//! verdict is a false positive) and one A/B experiment (v1 vs v2 —
//! detection is scored against the generator's ground truth), then
//! aggregates false-positive rate, detection rate and billed cost per
//! verdict into a [`StrategyScoreRow`]. The rendered scoreboard is the
//! headline artifact; CI exports the same numbers as
//! `BENCH_strategies.json` when `ELASTIBENCH_STRATEGY_BENCH_JSON` names
//! a path.
//!
//! Hard gates only bind the `duet` strategy — the paper's design point:
//! its A/A false-positive rate must stay within the analyzer's alpha
//! (<= 5% of verdicts) and it must find >= 90% of the injected changes
//! whose FaaS-side magnitude is >= 10% (the floor
//! `exp::tests::baseline_detects_large_true_changes` asserts at 100%).
//! The other strategies are measured, not gated: the scoreboard exists
//! to show what duet buys relative to sequential/RMIT scheduling.
//!
//! `ELASTIBENCH_STRATEGY_SMOKE=1` trims the grid to the aws-lambda
//! column (all strategies, both regimes) for the CI smoke job.

use elastibench::config::{ExperimentConfig, PlatformConfig, SutConfig};
use elastibench::coordinator::{reference, run_experiment, run_experiment_with, StrategyKind};
use elastibench::faas::profile_by_name;
use elastibench::report::{strategy_scoreboard_table, StrategyScoreRow};
use elastibench::stats::Analyzer;
use elastibench::sut::{generate, Suite, Version};
use elastibench::util::benchkit::BenchReport;

/// Seed offset between run seed and analysis seed (the convention the
/// scenario runner and experiment drivers share).
const ANALYSIS_SEED_XOR: u64 = 0xA11A;

const PROFILES: &[&str] = &["aws-lambda", "gcp-cloud-functions", "azure-functions"];

/// Lab SUT: every benchmark FaaS-runnable (no FS writers, no slow
/// setups), five injected true changes so the generator's big magnitude
/// ladder (116%, 62%, 28%, 22%, ...) engages.
fn lab_sut() -> SutConfig {
    SutConfig {
        benchmark_count: 12,
        true_changes: 5,
        faas_incompatible: 0,
        slow_setup: 0,
        ..SutConfig::default()
    }
}

/// 10 calls x 3 in-call repeats = 30 results per benchmark: enough
/// bootstrap power for the >= 10% ground-truth changes in every regime,
/// small enough that the full 4 x 3 x 2 grid stays in test time.
fn lab_exp(label: &str, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        label: label.into(),
        calls_per_benchmark: 10,
        parallelism: 30,
        seed,
        ..ExperimentConfig::default()
    }
}

/// The "noisy" regime: the same provider calibration under amplified
/// multi-tenant weather — wider instance heterogeneity, a stronger
/// co-tenancy AR(1) and a doubled diurnal swing.
fn amplify_noise(mut cfg: PlatformConfig) -> PlatformConfig {
    cfg.instance_sigma *= 2.0;
    cfg.cotenancy_sigma *= 3.0;
    cfg.diurnal_amplitude = (cfg.diurnal_amplitude * 2.0).min(0.15);
    cfg
}

/// Injected changes the harness scores detection over: FaaS-runnable,
/// not a benchmark-code change (its measured magnitude is inconsistent
/// by design), and with a FaaS-side ground truth of at least 10% — the
/// magnitude class the analyzer is calibrated to always find.
fn detectable_changes(suite: &Suite) -> Vec<String> {
    suite
        .benchmarks
        .iter()
        .filter(|b| {
            b.has_true_change()
                && !b.benchmark_changed()
                && !b.writes_fs
                && b.setup_s < 6.0
                && b.true_change_pct(true).abs() >= 10.0
        })
        .map(|b| b.name.clone())
        .collect()
}

/// Run one scoreboard cell: A/A then A/B under `kind`, analyzed with
/// the shared analyzer-seed convention.
#[allow(clippy::too_many_arguments)]
fn score_cell(
    suite: &Suite,
    sut: &SutConfig,
    platform: &PlatformConfig,
    kind: StrategyKind,
    profile: &str,
    noise: &str,
    seed: u64,
    analyzer: &Analyzer,
    detectable: &[String],
) -> StrategyScoreRow {
    let strategy = kind.strategy();

    let exp_aa = lab_exp(&format!("lab-aa-{}-{profile}-{noise}", kind.as_str()), seed);
    let aa_run = run_experiment_with(
        suite,
        sut,
        platform,
        &exp_aa,
        (Version::V1, Version::V1),
        strategy,
    );
    let aa = analyzer
        .analyze(&exp_aa.label, &aa_run.measurements, exp_aa.seed ^ ANALYSIS_SEED_XOR)
        .expect("analyze A/A");

    let exp_ab = lab_exp(&format!("lab-ab-{}-{profile}-{noise}", kind.as_str()), seed ^ 0xAB);
    let ab_run = run_experiment_with(
        suite,
        sut,
        platform,
        &exp_ab,
        (Version::V1, Version::V2),
        strategy,
    );
    let ab = analyzer
        .analyze(&exp_ab.label, &ab_run.measurements, exp_ab.seed ^ ANALYSIS_SEED_XOR)
        .expect("analyze A/B");

    let ab_detected = detectable
        .iter()
        .filter(|name| ab.get(name).is_some_and(|v| v.change.is_change()))
        .count();
    let verdicts = aa.verdicts.len() + ab.verdicts.len();
    let cost = aa_run.cost_usd + ab_run.cost_usd;
    StrategyScoreRow {
        strategy: kind.as_str().to_string(),
        profile: profile.to_string(),
        noise: noise.to_string(),
        aa_false_positives: aa.change_count(),
        aa_verdicts: aa.verdicts.len(),
        ab_detected,
        ab_injected: detectable.len(),
        cost_per_verdict_usd: if verdicts == 0 { 0.0 } else { cost / verdicts as f64 },
    }
}

#[test]
fn scoreboard_scores_every_strategy_profile_and_noise_regime() {
    let smoke = std::env::var("ELASTIBENCH_STRATEGY_SMOKE").is_ok();
    let profiles: &[&str] = if smoke { &PROFILES[..1] } else { PROFILES };

    let analyzer = Analyzer::native();
    let sut = lab_sut();
    let suite = generate(&sut);
    let detectable = detectable_changes(&suite);
    assert!(
        detectable.len() >= 3,
        "lab SUT must inject >= 3 large detectable changes, got {detectable:?}"
    );

    let mut rows: Vec<StrategyScoreRow> = Vec::new();
    for (si, kind) in StrategyKind::all().into_iter().enumerate() {
        for (pi, profile) in profiles.iter().enumerate() {
            let base = profile_by_name(profile).expect("registered profile").config();
            for (ni, (noise, amplified)) in
                [("quiet", false), ("noisy", true)].into_iter().enumerate()
            {
                let platform = if amplified { amplify_noise(base.clone()) } else { base.clone() };
                let seed = 0x57AB_0000 + (si as u64) * 0x100 + (pi as u64) * 0x10 + ni as u64;
                rows.push(score_cell(
                    &suite, &sut, &platform, kind, profile, noise, seed, &analyzer, &detectable,
                ));
            }
        }
    }

    // Full coverage: one row per strategy x profile x regime, and every
    // cell produced analyzable verdicts in both halves.
    assert_eq!(rows.len(), StrategyKind::all().len() * profiles.len() * 2);
    for r in &rows {
        assert!(
            r.aa_verdicts >= suite.len() / 2,
            "{}/{}/{}: only {} A/A verdicts",
            r.strategy,
            r.profile,
            r.noise,
            r.aa_verdicts
        );
        assert_eq!(r.ab_injected, detectable.len());
        assert!(
            r.cost_per_verdict_usd > 0.0,
            "{}/{}/{}: zero cost per verdict",
            r.strategy,
            r.profile,
            r.noise
        );
    }

    println!("{}", strategy_scoreboard_table(&rows));

    // Hard gates on the paper's design point.
    let duet: Vec<&StrategyScoreRow> =
        rows.iter().filter(|r| r.strategy == "duet").collect();
    assert_eq!(duet.len(), profiles.len() * 2);
    let fp: usize = duet.iter().map(|r| r.aa_false_positives).sum();
    let verdicts: usize = duet.iter().map(|r| r.aa_verdicts).sum();
    let fp_pct = fp as f64 / verdicts as f64 * 100.0;
    assert!(
        fp_pct <= 5.0,
        "duet A/A false-positive rate {fp_pct:.1}% ({fp}/{verdicts}) exceeds 5%"
    );
    for r in &duet {
        assert!(
            r.aa_false_positives <= 1,
            "duet {}/{}: {} A/A false positives in one cell",
            r.profile,
            r.noise,
            r.aa_false_positives
        );
        assert!(
            r.detection_pct() >= 90.0,
            "duet {}/{}: detected {}/{} injected changes",
            r.profile,
            r.noise,
            r.ab_detected,
            r.ab_injected
        );
    }

    // CI artifact: the same scoreboard as a bench-report document.
    if let Ok(path) = std::env::var("ELASTIBENCH_STRATEGY_BENCH_JSON") {
        let mut bench = BenchReport::new("strategies");
        for r in &rows {
            let key = format!("{}.{}.{}", r.strategy, r.profile, r.noise);
            bench.metric(&format!("{key}.aa_fp_pct"), r.aa_fp_pct());
            bench.metric(&format!("{key}.detection_pct"), r.detection_pct());
            bench.metric(&format!("{key}.cost_per_verdict_usd"), r.cost_per_verdict_usd);
        }
        bench.metric("duet.aa_fp_pct_overall", fp_pct);
        bench
            .write(std::path::Path::new(&path))
            .expect("write BENCH_strategies.json");
    }
}

/// The headline refactor guarantee, re-stated at the lab's own config:
/// routing through the extracted `duet` strategy object — via the trait
/// entry point or the delegating default API — reproduces the frozen
/// pre-extraction coordinator byte for byte (f64 Debug formatting is
/// shortest-round-trip, so equal strings mean bit-equal reports).
#[test]
fn duet_strategy_is_byte_identical_to_the_frozen_reference() {
    let sut = lab_sut();
    let suite = generate(&sut);
    let platform = profile_by_name("aws-lambda").expect("profile").config();
    let exp = lab_exp("duet-identity", 0x1DE7_0001);

    let frozen = reference::run_experiment_hardcoded(
        &suite,
        &sut,
        &platform,
        &exp,
        (Version::V1, Version::V2),
    );
    let via_trait = run_experiment_with(
        &suite,
        &sut,
        &platform,
        &exp,
        (Version::V1, Version::V2),
        StrategyKind::Duet.strategy(),
    );
    let via_default =
        run_experiment(&suite, &sut, &platform, &exp, (Version::V1, Version::V2));

    assert_eq!(format!("{via_trait:?}"), format!("{frozen:?}"));
    assert_eq!(format!("{via_default:?}"), format!("{frozen:?}"));
}
