//! Chaos accuracy lab: the A/A + A/B accuracy scoreboard under injected
//! platform faults, fault regime x provider calibration x retry policy.
//!
//! Each cell runs one A/A experiment (both lanes v1 — every change
//! verdict is a false positive) and one A/B experiment (v1 vs v2 —
//! detection scored against the generator's ground truth) through
//! [`run_experiment_chaos`] with the cell's [`FaultSpec`] installed,
//! then aggregates false-positive rate, detection rate, quarantined
//! benchmarks, injected faults and the billed retry/hedge overhead into
//! a [`ChaosScoreRow`]. The rendered scoreboard is the headline
//! artifact; CI exports the same numbers as `BENCH_chaos.json` when
//! `ELASTIBENCH_CHAOS_BENCH_JSON` names a path.
//!
//! Hard gates bind the `standard` policy — the shipped default: under
//! the `standard` fault regime its A/A false-positive rate must stay
//! within the analyzer's alpha (<= 5% of verdicts) and it must find
//! >= 90% of the injected changes whose FaaS-side magnitude is >= 10%.
//! The `legacy` policy (retry budgets off) is measured in the same
//! cells, and the harness asserts the contrast: switching the policy
//! off must demonstrably degrade at least one score under the standard
//! regime — otherwise the policy is dead weight.
//!
//! `ELASTIBENCH_CHAOS_SMOKE=1` trims the grid to the standard regime on
//! aws-lambda (both policies) for the CI smoke job.
//! `ELASTIBENCH_CHAOS_MAX_AA_FP_PCT` / `ELASTIBENCH_CHAOS_MIN_DETECTION_PCT`
//! override the gate thresholds — CI uses an impossible threshold to
//! assert that a red scoreboard really fails the test binary.

use elastibench::config::{ExperimentConfig, PlatformConfig, SutConfig};
use elastibench::coordinator::{run_experiment_chaos, RetryPolicy, StrategyKind};
use elastibench::faas::{profile_by_name, FaultSpec};
use elastibench::report::{chaos_scoreboard_table, ChaosScoreRow};
use elastibench::scenario::quarantine_degraded;
use elastibench::stats::{Analyzer, SuiteAnalysis};
use elastibench::sut::{generate, Suite, Version};
use elastibench::telemetry::{RecordingSink, RunMetrics, SharedSink};
use elastibench::util::benchkit::BenchReport;

/// Seed offset between run seed and analysis seed (the convention the
/// scenario runner and experiment drivers share).
const ANALYSIS_SEED_XOR: u64 = 0xA11A;

/// A gate threshold, overridable via environment for the CI red-path
/// check (an impossible threshold must fail the binary — the exit-code
/// contract of the gate).
fn gate_pct(var: &str, default: f64) -> f64 {
    std::env::var(var)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

const PROFILES: &[&str] = &["aws-lambda", "gcp-cloud-functions", "azure-functions"];

/// Every active fault regime on the board.
const REGIMES: &[&str] = &["standard", "throttle-storm", "spot-chaos", "brownout"];

/// Lab SUT: every benchmark FaaS-runnable, five injected true changes
/// so the generator's big magnitude ladder engages.
fn lab_sut() -> SutConfig {
    SutConfig {
        benchmark_count: 12,
        true_changes: 5,
        faas_incompatible: 0,
        slow_setup: 0,
        ..SutConfig::default()
    }
}

/// 6 calls x 2 in-call repeats = 12 results per benchmark — just above
/// the analyzer's 10-sample floor, so fault-induced sample loss is what
/// separates the policies: one unrecovered crash costs 2 samples, two
/// drop the benchmark below the quorum.
fn lab_exp(label: &str, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        label: label.into(),
        calls_per_benchmark: 6,
        repeats_per_call: 2,
        parallelism: 30,
        seed,
        ..ExperimentConfig::default()
    }
}

/// Injected changes the harness scores detection over: FaaS-runnable,
/// not a benchmark-code change, and with a FaaS-side ground truth of at
/// least 10% — the magnitude class the analyzer is calibrated to find.
fn detectable_changes(suite: &Suite) -> Vec<String> {
    suite
        .benchmarks
        .iter()
        .filter(|b| {
            b.has_true_change()
                && !b.benchmark_changed()
                && !b.writes_fs
                && b.setup_s < 6.0
                && b.true_change_pct(true).abs() >= 10.0
        })
        .map(|b| b.name.clone())
        .collect()
}

/// One faulted experiment half (A/A or A/B): run under the regime and
/// policy, quarantine quorum-starved benchmarks, analyze the rest.
/// Returns the analysis, quarantined count, span-derived metrics and
/// billed cost.
fn run_half(
    suite: &Suite,
    sut: &SutConfig,
    platform: &PlatformConfig,
    exp: &ExperimentConfig,
    versions: (Version, Version),
    faults: &FaultSpec,
    policy: &RetryPolicy,
    analyzer: &Analyzer,
) -> (SuiteAnalysis, usize, RunMetrics, f64) {
    let rec = RecordingSink::shared();
    let sink: SharedSink = rec.clone();
    let (run, _) = run_experiment_chaos(
        suite,
        sut,
        platform,
        exp,
        versions,
        StrategyKind::Duet.strategy(),
        Some(faults),
        policy,
        None,
        Some(&sink),
    );
    let spans = std::mem::take(&mut rec.borrow_mut().spans);
    let metrics = RunMetrics::from_spans(
        &spans,
        run.cost_usd,
        exp.memory_mb as f64 / 1024.0,
        platform.usd_per_gb_s,
        platform.usd_per_request,
    );
    let mut measurements = run.measurements;
    let degraded = quarantine_degraded(&mut measurements, policy.min_quorum);
    let analysis = analyzer
        .analyze(&exp.label, &measurements, exp.seed ^ ANALYSIS_SEED_XOR)
        .expect("analyze faulted run");
    (analysis, degraded.len(), metrics, run.cost_usd)
}

/// Run one scoreboard cell: A/A then A/B under (regime, profile,
/// policy).
fn score_cell(
    suite: &Suite,
    sut: &SutConfig,
    platform: &PlatformConfig,
    faults: &FaultSpec,
    profile: &str,
    policy: &RetryPolicy,
    seed: u64,
    analyzer: &Analyzer,
    detectable: &[String],
) -> ChaosScoreRow {
    let exp_aa = lab_exp(
        &format!("chaos-aa-{}-{profile}-{}", faults.regime, policy.name),
        seed,
    );
    let (aa, aa_deg, aa_m, aa_cost) = run_half(
        suite,
        sut,
        platform,
        &exp_aa,
        (Version::V1, Version::V1),
        faults,
        policy,
        analyzer,
    );
    let exp_ab = lab_exp(
        &format!("chaos-ab-{}-{profile}-{}", faults.regime, policy.name),
        seed ^ 0xAB,
    );
    let (ab, ab_deg, ab_m, ab_cost) = run_half(
        suite,
        sut,
        platform,
        &exp_ab,
        (Version::V1, Version::V2),
        faults,
        policy,
        analyzer,
    );
    let ab_detected = detectable
        .iter()
        .filter(|name| ab.get(name).is_some_and(|v| v.change.is_change()))
        .count();
    ChaosScoreRow {
        regime: faults.regime.clone(),
        profile: profile.to_string(),
        policy: policy.name.clone(),
        aa_false_positives: aa.change_count(),
        aa_verdicts: aa.verdicts.len(),
        ab_detected,
        ab_injected: detectable.len(),
        degraded: aa_deg + ab_deg,
        faults_injected: aa_m.faults_injected + ab_m.faults_injected,
        retry_cost_usd: aa_m.cost_retry_usd + ab_m.cost_retry_usd,
        hedge_cost_usd: aa_m.cost_hedge_usd + ab_m.cost_hedge_usd,
        cost_usd: aa_cost + ab_cost,
    }
}

#[test]
fn chaos_scoreboard_gates_the_default_policy_and_shows_the_contrast() {
    let smoke = std::env::var("ELASTIBENCH_CHAOS_SMOKE").is_ok();
    let profiles: &[&str] = if smoke { &PROFILES[..1] } else { PROFILES };
    let regimes: &[&str] = if smoke { &REGIMES[..1] } else { REGIMES };

    let analyzer = Analyzer::native();
    let sut = lab_sut();
    let suite = generate(&sut);
    let detectable = detectable_changes(&suite);
    assert!(
        detectable.len() >= 3,
        "lab SUT must inject >= 3 large detectable changes, got {detectable:?}"
    );

    let policies = [RetryPolicy::standard(), RetryPolicy::legacy()];
    let mut rows: Vec<ChaosScoreRow> = Vec::new();
    for (ri, regime) in regimes.iter().enumerate() {
        let faults = FaultSpec::regime(regime).expect("registered regime");
        for (pi, profile) in profiles.iter().enumerate() {
            let platform = profile_by_name(profile).expect("registered profile").config();
            for (oi, policy) in policies.iter().enumerate() {
                let seed = 0xC4A0_0000
                    + (ri as u64) * 0x1000
                    + (pi as u64) * 0x100
                    + (oi as u64) * 0x10;
                rows.push(score_cell(
                    &suite,
                    &sut,
                    &platform,
                    &faults,
                    profile,
                    policy,
                    seed,
                    &analyzer,
                    &detectable,
                ));
            }
        }
    }

    // Full coverage: one row per regime x profile x policy, every cell
    // actually injected faults and billed something.
    assert_eq!(rows.len(), regimes.len() * profiles.len() * policies.len());
    for r in &rows {
        assert!(
            r.faults_injected > 0,
            "{}/{}/{}: regime injected nothing",
            r.regime,
            r.profile,
            r.policy
        );
        assert!(r.cost_usd > 0.0, "{}/{}/{}: zero billed cost", r.regime, r.profile, r.policy);
        assert_eq!(r.ab_injected, detectable.len());
        // The legacy policy never hedges (threshold off) or quarantines
        // (quorum off) — those scores are structurally zero. Its single
        // immediate crash retry can still bill retry cost.
        if r.policy == "legacy" {
            assert_eq!(r.degraded, 0, "{}/{}: legacy quarantined", r.regime, r.profile);
            assert_eq!(r.hedge_cost_usd, 0.0, "{}/{}: legacy hedged", r.regime, r.profile);
        }
    }

    println!("{}", chaos_scoreboard_table(&rows));

    // Hard gates on the shipped default: the standard policy under the
    // standard regime, aggregated across profiles.
    let std_rows: Vec<&ChaosScoreRow> = rows
        .iter()
        .filter(|r| r.regime == "standard" && r.policy == "standard")
        .collect();
    assert_eq!(std_rows.len(), profiles.len());
    let fp: usize = std_rows.iter().map(|r| r.aa_false_positives).sum();
    let verdicts: usize = std_rows.iter().map(|r| r.aa_verdicts).sum();
    let fp_pct = fp as f64 / verdicts.max(1) as f64 * 100.0;
    let max_fp_pct = gate_pct("ELASTIBENCH_CHAOS_MAX_AA_FP_PCT", 5.0);
    assert!(
        fp_pct <= max_fp_pct,
        "standard policy A/A false-positive rate {fp_pct:.1}% ({fp}/{verdicts}) exceeds \
         {max_fp_pct}%"
    );
    let detected: usize = std_rows.iter().map(|r| r.ab_detected).sum();
    let injected: usize = std_rows.iter().map(|r| r.ab_injected).sum();
    let detection_pct = detected as f64 / injected.max(1) as f64 * 100.0;
    let min_detection_pct = gate_pct("ELASTIBENCH_CHAOS_MIN_DETECTION_PCT", 90.0);
    assert!(
        detection_pct >= min_detection_pct,
        "standard policy detected {detected}/{injected} ({detection_pct:.1}%) under the \
         standard regime (gate: >= {min_detection_pct}%)"
    );

    // The contrast: turning the policy off must degrade at least one
    // score under the standard regime — fewer detections, more false
    // positives, or benchmarks silently starved out of the analysis.
    let leg_rows: Vec<&ChaosScoreRow> = rows
        .iter()
        .filter(|r| r.regime == "standard" && r.policy == "legacy")
        .collect();
    let leg_detected: usize = leg_rows.iter().map(|r| r.ab_detected).sum();
    let leg_fp: usize = leg_rows.iter().map(|r| r.aa_false_positives).sum();
    let leg_verdicts: usize = leg_rows.iter().map(|r| r.aa_verdicts).sum();
    let leg_fp_pct = leg_fp as f64 / leg_verdicts.max(1) as f64 * 100.0;
    assert!(
        leg_detected < detected || leg_fp_pct > fp_pct || leg_verdicts < verdicts,
        "legacy policy must degrade at least one score under the standard regime: \
         detected {leg_detected} vs {detected}, A/A FP {leg_fp_pct:.1}% vs {fp_pct:.1}%, \
         analyzed {leg_verdicts} vs {verdicts}"
    );

    // CI artifact: the same scoreboard as a bench-report document.
    if let Ok(path) = std::env::var("ELASTIBENCH_CHAOS_BENCH_JSON") {
        let mut bench = BenchReport::new("chaos");
        for r in &rows {
            let key = format!("{}.{}.{}", r.regime, r.profile, r.policy);
            bench.metric(&format!("{key}.aa_fp_pct"), r.aa_fp_pct());
            bench.metric(&format!("{key}.detection_pct"), r.detection_pct());
            bench.metric(&format!("{key}.degraded"), r.degraded as f64);
            bench.metric(&format!("{key}.faults_injected"), r.faults_injected as f64);
            bench.metric(&format!("{key}.overhead_pct"), r.overhead_pct());
        }
        bench.metric("standard.aa_fp_pct_overall", fp_pct);
        bench.metric("standard.detection_pct_overall", detection_pct);
        bench
            .write(std::path::Path::new(&path))
            .expect("write BENCH_chaos.json");
    }
}

/// Faulted runs are pure functions of (recipe, seed): the same cell
/// executed twice yields bit-identical reports (f64 Debug formatting is
/// shortest-round-trip, so equal strings mean bit-equal values).
#[test]
fn faulted_cells_are_deterministic_across_repeats() {
    let sut = lab_sut();
    let suite = generate(&sut);
    let platform = profile_by_name("aws-lambda").expect("profile").config();
    let faults = FaultSpec::regime("spot-chaos").expect("regime");
    let policy = RetryPolicy::standard();
    let exp = lab_exp("chaos-repeat", 0xC4A0_FFFF);
    let run_once = || {
        run_experiment_chaos(
            &suite,
            &sut,
            &platform,
            &exp,
            (Version::V1, Version::V2),
            StrategyKind::Duet.strategy(),
            Some(&faults),
            &policy,
            None,
            None,
        )
        .0
    };
    let a = run_once();
    let b = run_once();
    assert_eq!(format!("{a:?}"), format!("{b:?}"));
}

/// Zero-impact guarantee: running through the chaos entry point with no
/// fault spec and the legacy policy reproduces the default path byte
/// for byte.
#[test]
fn absent_faults_with_legacy_policy_are_byte_identical_to_the_default_path() {
    let sut = lab_sut();
    let suite = generate(&sut);
    let platform = profile_by_name("gcp-cloud-functions").expect("profile").config();
    let exp = lab_exp("chaos-absent", 0xC4A0_1DE7);
    let plain = elastibench::coordinator::run_experiment_with(
        &suite,
        &sut,
        &platform,
        &exp,
        (Version::V1, Version::V2),
        StrategyKind::Duet.strategy(),
    );
    let chaos = run_experiment_chaos(
        &suite,
        &sut,
        &platform,
        &exp,
        (Version::V1, Version::V2),
        StrategyKind::Duet.strategy(),
        None,
        &RetryPolicy::legacy(),
        None,
        None,
    )
    .0;
    assert_eq!(format!("{chaos:?}"), format!("{plain:?}"));
}
