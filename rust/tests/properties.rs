//! Property-based tests (in-tree `testkit`) over the statistics engine,
//! the simulators, and the coordinator invariants.

use elastibench::config::{ExperimentConfig, PlatformConfig, SutConfig, VmConfig};
use elastibench::coordinator::run_experiment;
use elastibench::stats::{agreement, bootstrap_native_single, Analyzer, Measurements};
use elastibench::sut::{generate, Version};
use elastibench::testkit::{check, Gen};
use elastibench::vm::run_vm_baseline;

// ---------- bootstrap engine ----------

#[test]
fn prop_ci_always_ordered_and_contains_point() {
    check("CI ordered", 60, |g: &mut Gen| {
        let n = g.usize(2..46);
        let v1: Vec<f32> = (0..n).map(|_| g.latency() as f32 + 0.01).collect();
        let v2: Vec<f32> = (0..n).map(|_| g.latency() as f32 + 0.01).collect();
        let mut idx = vec![0i32; 256 * 64];
        g.rng().fill_index_bits(&mut idx);
        let o = bootstrap_native_single(&v1, &v2, &idx, 256, 64, 0.01);
        assert!(o.ci_lo_pct <= o.boot_median_pct);
        assert!(o.boot_median_pct <= o.ci_hi_pct);
        assert!(o.median_v1 > 0.0 && o.median_v2 > 0.0);
    });
}

#[test]
fn prop_scaling_both_versions_preserves_diff() {
    // Scaling both versions by the same factor (a different instance)
    // must leave the relative difference unchanged — the duet argument.
    check("common scale invariance", 40, |g: &mut Gen| {
        let n = g.usize(5..40);
        let scale = g.f64(0.25..4.0) as f32;
        let v1: Vec<f32> = (0..n).map(|_| g.latency() as f32 + 0.01).collect();
        let v2: Vec<f32> = (0..n).map(|_| g.latency() as f32 + 0.01).collect();
        let s1: Vec<f32> = v1.iter().map(|x| x * scale).collect();
        let s2: Vec<f32> = v2.iter().map(|x| x * scale).collect();
        let mut idx = vec![0i32; 128 * 64];
        g.rng().fill_index_bits(&mut idx);
        let a = bootstrap_native_single(&v1, &v2, &idx, 128, 64, 0.01);
        let b = bootstrap_native_single(&s1, &s2, &idx, 128, 64, 0.01);
        let close = |x: f32, y: f32| (x - y).abs() < 1e-3 + 1e-4 * x.abs().max(y.abs());
        assert!(close(a.boot_median_pct, b.boot_median_pct));
        assert!(close(a.ci_lo_pct, b.ci_lo_pct));
        assert!(close(a.ci_hi_pct, b.ci_hi_pct));
    });
}

#[test]
fn prop_swapping_versions_flips_direction() {
    check("antisymmetry", 40, |g: &mut Gen| {
        let n = g.usize(5..40);
        let v1: Vec<f32> = (0..n).map(|_| g.latency() as f32 + 0.01).collect();
        let v2: Vec<f32> = v1.iter().map(|x| x * 1.3).collect();
        let mut idx = vec![0i32; 128 * 64];
        g.rng().fill_index_bits(&mut idx);
        let fwd = bootstrap_native_single(&v1, &v2, &idx, 128, 64, 0.01);
        let rev = bootstrap_native_single(&v2, &v1, &idx, 128, 64, 0.01);
        assert_eq!(fwd.direction(), 1);
        assert_eq!(rev.direction(), -1);
    });
}

#[test]
fn prop_more_samples_tighter_ci() {
    check("CI shrinks with n", 25, |g: &mut Gen| {
        let sigma = g.f64(0.02..0.2);
        let base: Vec<f32> = (0..120)
            .map(|_| g.rng().lognormal(0.0, sigma) as f32)
            .collect();
        let v2: Vec<f32> = (0..120)
            .map(|_| (g.rng().lognormal(0.0, sigma) * 1.05) as f32)
            .collect();
        let mut idx = vec![0i32; 512 * 256];
        g.rng().fill_index_bits(&mut idx);
        let small = bootstrap_native_single(&base[..12], &v2[..12], &idx, 512, 256, 0.01);
        let large = bootstrap_native_single(&base, &v2, &idx, 512, 256, 0.01);
        // Allow slack: individual draws are noisy, but 10x samples should
        // rarely widen the CI by more than 40%.
        assert!(
            large.ci_size_pct() < small.ci_size_pct() * 1.4,
            "n=120 CI {} vs n=12 CI {}",
            large.ci_size_pct(),
            small.ci_size_pct()
        );
    });
}

// ---------- analyzer ----------

#[test]
fn prop_analyzer_excludes_short_measurements() {
    let analyzer = Analyzer::native();
    check("min-results filter", 30, |g: &mut Gen| {
        let n_short = g.usize(0..10);
        let n_long = g.usize(10..50);
        let ms = vec![
            Measurements {
                name: "short".into(),
                v1: (0..n_short).map(|_| g.latency()).collect(),
                v2: (0..n_short).map(|_| g.latency()).collect(),
            },
            Measurements {
                name: "long".into(),
                v1: (0..n_long).map(|_| g.latency()).collect(),
                v2: (0..n_long).map(|_| g.latency()).collect(),
            },
        ];
        let out = analyzer.analyze("t", &ms, g.case as u64).expect("analyze");
        assert_eq!(out.excluded, vec!["short".to_string()]);
        assert_eq!(out.verdicts.len(), 1);
    });
}

// ---------- suite generator ----------

#[test]
fn prop_generator_respects_budgets() {
    check("generator budgets", 20, |g: &mut Gen| {
        let count = g.usize(10..140);
        let changes = g.usize(0..count.min(30));
        let fs = g.usize(0..count / 3);
        let cfg = SutConfig {
            benchmark_count: count,
            true_changes: changes,
            faas_incompatible: fs,
            slow_setup: g.usize(0..4),
            seed: g.u64(0..u64::MAX),
            ..SutConfig::default()
        };
        let suite = generate(&cfg);
        assert_eq!(suite.len(), count);
        let fs_count = suite.benchmarks.iter().filter(|b| b.writes_fs).count();
        assert!(fs_count <= fs);
        for b in &suite.benchmarks {
            assert!(b.base_ns_per_op > 0.0);
            assert!(b.rel_sigma > 0.0 && b.rel_sigma < 0.5);
            assert!(b.effect_v2 > 0.0);
        }
    });
}

// ---------- coordinator invariants ----------

#[test]
fn prop_coordinator_conserves_results() {
    check("results conservation", 8, |g: &mut Gen| {
        let sut = SutConfig {
            benchmark_count: g.usize(6..14),
            true_changes: 2,
            faas_incompatible: 1,
            slow_setup: 1,
            seed: g.u64(0..u64::MAX),
            ..SutConfig::default()
        };
        let suite = generate(&sut);
        let exp = ExperimentConfig {
            calls_per_benchmark: g.usize(2..8),
            repeats_per_call: g.usize(1..4),
            parallelism: g.usize(1..40),
            seed: g.u64(0..u64::MAX),
            ..ExperimentConfig::default()
        };
        let report = run_experiment(
            &suite,
            &sut,
            &PlatformConfig::default(),
            &exp,
            (Version::V1, Version::V2),
        );
        // Calls: exactly the plan (no crashes configured).
        assert_eq!(report.calls_total, suite.len() * exp.calls_per_benchmark);
        // Pairs never exceed the plan per benchmark; paired lengths equal.
        for m in &report.measurements {
            assert!(m.v1.len() == m.v2.len());
            assert!(m.len() <= exp.results_per_benchmark());
            assert!(m.v1.iter().all(|&x| x > 0.0));
        }
        // Billing: cost grows with billed GB-s.
        assert!(report.cost_usd > 0.0);
        assert!(report.platform.billed_gb_s > 0.0);
        // Wall time covers the critical path of any single call.
        assert!(report.invoke_wall_s > 0.0);
    });
}

#[test]
fn prop_strategy_schedules_are_pure_functions_of_seed_and_recipe() {
    // Every execution strategy's call schedule must be a pure function
    // of (seed, experiment shape): re-planning from the same seed yields
    // the identical schedule, different seeds reshuffle it, and the
    // planned call multiset always covers every benchmark exactly
    // `calls_per_benchmark` times per lane. Full runs re-executed from
    // the same inputs must reproduce identical measurements — worker
    // count never enters the schedule (sweep-level jobs-invariance is
    // pinned in rust/tests/scenario_catalog.rs).
    use elastibench::coordinator::strategy::CallSlot;
    use elastibench::coordinator::{run_experiment_with, StrategyKind};
    use elastibench::util::Rng;

    check("strategy schedule purity", 6, |g: &mut Gen| {
        let suite_len = g.usize(4..12);
        let exp = ExperimentConfig {
            calls_per_benchmark: g.usize(2..7),
            repeats_per_call: g.usize(1..4),
            parallelism: g.usize(1..30),
            seed: g.u64(0..u64::MAX),
            ..ExperimentConfig::default()
        };
        for kind in StrategyKind::all() {
            let strategy = kind.strategy();
            let plan_a = strategy.plan(suite_len, &exp, &mut Rng::new(exp.seed));
            let plan_b = strategy.plan(suite_len, &exp, &mut Rng::new(exp.seed));
            assert_eq!(plan_a, plan_b, "{}: same seed, same schedule", kind.as_str());

            let lanes_per_bench = match kind {
                StrategyKind::Sequential => 2,
                _ => 1,
            };
            assert_eq!(
                plan_a.len(),
                suite_len * exp.calls_per_benchmark * lanes_per_bench,
                "{}: schedule covers the plan exactly",
                kind.as_str()
            );
            for idx in 0..suite_len {
                let calls = plan_a.iter().filter(|p| p.bench_idx == idx).count();
                assert_eq!(
                    calls,
                    exp.calls_per_benchmark * lanes_per_bench,
                    "{}: benchmark {idx} call budget",
                    kind.as_str()
                );
            }
            if kind == StrategyKind::Sequential {
                for lane in [0u8, 1] {
                    let n = plan_a
                        .iter()
                        .filter(|p| p.slot == CallSlot::Single(lane))
                        .count();
                    assert_eq!(n, suite_len * exp.calls_per_benchmark, "lane {lane}");
                }
            }

            // A different seed must produce a different shuffle for any
            // non-trivial plan (astronomically unlikely to collide).
            if plan_a.len() >= 8 {
                let other = strategy.plan(suite_len, &exp, &mut Rng::new(exp.seed ^ 0x5EED));
                assert_ne!(plan_a, other, "{}: seed must drive the order", kind.as_str());
            }
        }
    });

    // Full-run determinism per strategy: identical inputs, identical
    // measurements — on a smaller budget since this simulates 4 runs.
    check("strategy run determinism", 2, |g: &mut Gen| {
        let sut = SutConfig {
            benchmark_count: 8,
            true_changes: 2,
            faas_incompatible: 1,
            slow_setup: 0,
            seed: g.u64(0..u64::MAX),
            ..SutConfig::default()
        };
        let suite = generate(&sut);
        let exp = ExperimentConfig {
            calls_per_benchmark: 4,
            parallelism: 12,
            seed: g.u64(0..u64::MAX),
            ..ExperimentConfig::default()
        };
        for kind in StrategyKind::all() {
            let strategy = kind.strategy();
            let a = run_experiment_with(
                &suite,
                &sut,
                &PlatformConfig::default(),
                &exp,
                (Version::V1, Version::V2),
                strategy,
            );
            let b = run_experiment_with(
                &suite,
                &sut,
                &PlatformConfig::default(),
                &exp,
                (Version::V1, Version::V2),
                strategy,
            );
            assert_eq!(a.wall_s, b.wall_s, "{}", kind.as_str());
            assert_eq!(a.cost_usd, b.cost_usd, "{}", kind.as_str());
            for (x, y) in a.measurements.iter().zip(&b.measurements) {
                assert_eq!(x.v1, y.v1, "{}: {}", kind.as_str(), x.name);
                assert_eq!(x.v2, y.v2, "{}: {}", kind.as_str(), x.name);
            }
        }
    });
}

#[test]
fn prop_experiments_deterministic_across_seeded_reruns() {
    check("determinism", 5, |g: &mut Gen| {
        let sut = SutConfig {
            benchmark_count: 8,
            true_changes: 2,
            faas_incompatible: 1,
            slow_setup: 0,
            seed: g.u64(0..u64::MAX),
            ..SutConfig::default()
        };
        let suite = generate(&sut);
        let exp = ExperimentConfig {
            calls_per_benchmark: 4,
            seed: g.u64(0..u64::MAX),
            ..ExperimentConfig::default()
        };
        let a = run_experiment(&suite, &sut, &PlatformConfig::default(), &exp, (Version::V1, Version::V2));
        let b = run_experiment(&suite, &sut, &PlatformConfig::default(), &exp, (Version::V1, Version::V2));
        assert_eq!(a.wall_s, b.wall_s);
        assert_eq!(a.cost_usd, b.cost_usd);
        for (x, y) in a.measurements.iter().zip(&b.measurements) {
            assert_eq!(x.v1, y.v1);
            assert_eq!(x.v2, y.v2);
        }
    });
}

// ---------- cross-platform sanity ----------

#[test]
fn prop_vm_and_faas_agree_on_large_effects() {
    // Whatever the seeds, a 100%+ regression must be detected by both
    // platforms with the same direction.
    let analyzer = Analyzer::native();
    check("large effects cross-platform", 3, |g: &mut Gen| {
        let sut = SutConfig {
            benchmark_count: 10,
            true_changes: 3,
            faas_incompatible: 1,
            slow_setup: 0,
            seed: g.u64(0..u64::MAX),
            ..SutConfig::default()
        };
        let suite = generate(&sut);
        let headline = suite
            .benchmarks
            .iter()
            .filter(|b| !b.writes_fs && !b.benchmark_changed())
            .max_by(|a, b| {
                a.true_change_pct(false)
                    .abs()
                    .partial_cmp(&b.true_change_pct(false).abs())
                    .unwrap()
            })
            .unwrap();
        if headline.true_change_pct(false).abs() < 20.0 {
            return; // this seed's ladder got truncated; nothing to assert
        }
        let exp = ExperimentConfig {
            seed: g.u64(0..u64::MAX),
            ..ExperimentConfig::default()
        };
        let faas = run_experiment(&suite, &sut, &PlatformConfig::default(), &exp, (Version::V1, Version::V2));
        let vm = run_vm_baseline(&suite, &sut, &VmConfig { seed: g.u64(0..u64::MAX), ..VmConfig::default() });
        let fa = analyzer.analyze("faas", &faas.measurements, 1).unwrap();
        let va = analyzer.analyze("vm", &vm.measurements, 1).unwrap();
        let f = fa.get(&headline.name).expect("faas verdict");
        let v = va.get(&headline.name).expect("vm verdict");
        assert!(f.change.is_change(), "{}: {:?}", headline.name, f.output);
        assert_eq!(f.change, v.change, "{}", headline.name);
        // And the two datasets agree overall on most benchmarks.
        let rep = agreement(&fa, &va);
        assert!(rep.agreement_pct() >= 70.0, "{}", rep.agreement_pct());
    });
}

#[test]
fn prop_faulted_runs_are_pure_functions_of_recipe_and_seed() {
    // Fault injection must not break determinism: whatever the regime,
    // policy and strategy, re-running from identical inputs yields a
    // bit-identical report AND a bit-identical lifecycle span stream
    // (sweep-level `--jobs` invariance over a faulted recipe is pinned
    // in rust/tests/scenario_catalog.rs).
    use elastibench::coordinator::{run_experiment_chaos, RetryPolicy, StrategyKind};
    use elastibench::faas::FaultSpec;
    use elastibench::telemetry::{RecordingSink, SharedSink, Span};

    let regimes = ["standard", "throttle-storm", "spot-chaos", "brownout"];
    check("faulted run purity", 2, |g: &mut Gen| {
        let sut = SutConfig {
            benchmark_count: 6,
            true_changes: 2,
            faas_incompatible: 0,
            slow_setup: 0,
            seed: g.u64(0..u64::MAX),
            ..SutConfig::default()
        };
        let suite = generate(&sut);
        let exp = ExperimentConfig {
            calls_per_benchmark: 4,
            repeats_per_call: 2,
            parallelism: g.usize(1..20),
            seed: g.u64(0..u64::MAX),
            ..ExperimentConfig::default()
        };
        let faults = FaultSpec::regime(regimes[g.usize(0..regimes.len())]).unwrap();
        let policy = if g.bool(0.5) { RetryPolicy::standard() } else { RetryPolicy::legacy() };
        for kind in StrategyKind::all() {
            let run_once = || -> (String, Vec<Span>) {
                let rec = RecordingSink::shared();
                let sink: SharedSink = rec.clone();
                let (report, _) = run_experiment_chaos(
                    &suite,
                    &sut,
                    &PlatformConfig::default(),
                    &exp,
                    (Version::V1, Version::V2),
                    kind.strategy(),
                    Some(&faults),
                    &policy,
                    None,
                    Some(&sink),
                );
                let spans = std::mem::take(&mut rec.borrow_mut().spans);
                (format!("{report:?}"), spans)
            };
            let (a_report, a_spans) = run_once();
            let (b_report, b_spans) = run_once();
            assert_eq!(
                a_report,
                b_report,
                "{}/{}/{}: faulted report must be deterministic",
                kind.as_str(),
                faults.regime,
                policy.name
            );
            assert_eq!(
                format!("{a_spans:?}"),
                format!("{b_spans:?}"),
                "{}/{}/{}: faulted span stream must be deterministic",
                kind.as_str(),
                faults.regime,
                policy.name
            );
        }
    });
}

// ---------- history importer round trip ----------

#[test]
fn prop_scenario_report_roundtrips_through_history_loader() {
    // The store's importer is the inverse of `scenario_report_to_json`:
    // export -> parse -> re-export must be byte-identical, whatever the
    // scenario shape (incl. adaptive replays, exclusions, failures).
    use elastibench::history::{parse_scenario_report, stored_run_to_json};
    use elastibench::report::scenario_report_to_json;
    use elastibench::scenario::{catalog_entry, run_scenario, RepeatPolicy};
    use elastibench::util::json::parse as parse_json;

    let analyzer = Analyzer::native();
    check("report round trip", 4, |g: &mut Gen| {
        let mut sc = catalog_entry("quick-smoke").unwrap();
        sc.sut.benchmark_count = g.usize(4..9);
        sc.sut.true_changes = g.usize(0..3);
        sc.sut.faas_incompatible = g.usize(0..2);
        sc.sut.slow_setup = 0;
        sc.sut.seed = g.u64(0..u64::MAX);
        sc.exp.seed = g.u64(0..u64::MAX);
        sc.exp.calls_per_benchmark = g.usize(4..7);
        sc.exp.parallelism = 8;
        if g.bool(0.5) {
            // Exercise the `adaptive` report section too.
            sc.repeats = RepeatPolicy::Adaptive;
        }
        if g.bool(0.5) {
            // Exercise the `faults` / `degraded` report sections too.
            use elastibench::faas::FaultSpec;
            let regimes = ["standard", "throttle-storm", "spot-chaos", "brownout"];
            let mut faults = FaultSpec::regime(regimes[g.usize(0..regimes.len())]).unwrap();
            if g.bool(0.3) {
                faults.policy = "legacy".to_string();
            }
            sc.faults = Some(faults);
        }
        let report = run_scenario(&sc, &analyzer).unwrap();
        let exported = scenario_report_to_json(&report).to_string();
        let stored = parse_scenario_report(&parse_json(&exported).unwrap()).unwrap();
        let reexported = stored_run_to_json(&stored).to_string();
        assert_eq!(exported, reexported, "history loader round trip is lossy");
    });
}
