//! Live adaptive early stopping, exercised end-to-end at the coordinator
//! boundary: the in-run incremental engine must stay in lockstep with the
//! post-hoc replay oracle on the streams the run actually produced, and
//! an A/A harness across all three provider calibrations checks that
//! stopping early does not buy its savings with false positives.

use elastibench::config::{ExperimentConfig, SutConfig};
use elastibench::coordinator::{run_experiment, run_experiment_live, LiveStopConfig};
use elastibench::faas::profile_by_name;
use elastibench::stats::{required_results, Analyzer, StoppingRule};
use elastibench::sut::{generate, Version};

/// Seed offset between run seed and analysis seed (the convention the
/// scenario runner and experiment drivers share).
const ANALYSIS_SEED_XOR: u64 = 0xA11A;

fn live_cfg(exp: &ExperimentConfig, analyzer: &Analyzer) -> LiveStopConfig {
    LiveStopConfig {
        b: analyzer.b,
        alpha: analyzer.alpha,
        min_results: analyzer.min_results,
        rule: StoppingRule {
            step: exp.repeats_per_call.max(1),
            ..StoppingRule::default()
        },
        seed: exp.seed ^ ANALYSIS_SEED_XOR,
    }
}

fn small_sut() -> SutConfig {
    SutConfig {
        benchmark_count: 12,
        true_changes: 3,
        faas_incompatible: 1,
        slow_setup: 1,
        ..SutConfig::default()
    }
}

/// Strict lockstep: for EVERY benchmark — decided, budget-exhausted,
/// failed or empty — the live engine's stop point equals
/// `required_results` replayed over the measurement stream the live run
/// itself collected. This is the tie-order-independence guarantee of the
/// incremental kernel surfacing at the system boundary: checkpoint
/// evaluations on the online rank state are bit-identical to fresh
/// prefix replays.
#[test]
fn live_stop_points_lockstep_with_replay_on_own_streams() {
    let sut = small_sut();
    let suite = generate(&sut);
    // Parallelism far below the planned call count, so there is a
    // backlog of scheduled-but-unissued calls for decisions to cancel.
    let exp = ExperimentConfig {
        parallelism: 12,
        ..ExperimentConfig::default()
    };
    let analyzer = Analyzer::native();
    let cfg = live_cfg(&exp, &analyzer);
    let platform = profile_by_name("aws-lambda").expect("profile").config();
    let (run, live) =
        run_experiment_live(&suite, &sut, &platform, &exp, (Version::V1, Version::V2), &cfg);

    assert_eq!(live.stop_points.len(), suite.len());
    assert!(live.decided > 0, "tight benchmarks must decide early");
    assert!(live.calls_canceled > 0, "decisions must cancel scheduled calls");
    let mut analyzable = 0usize;
    for m in &run.measurements {
        let (_, stop) = live
            .stop_points
            .iter()
            .find(|(n, _)| n == &m.name)
            .expect("a stop point for every benchmark");
        let needed = required_results(&analyzer, m, &cfg.rule, cfg.seed).expect("replay");
        assert_eq!(*stop, needed, "{}", m.name);
        if m.len() >= cfg.rule.min_results {
            analyzable += 1;
        }
    }
    assert!(analyzable > 0, "at least one stream reaches the analysis floor");
}

/// A/A harness across the three provider calibrations: with identical
/// versions, the live early-stopped run must not report more change
/// verdicts (false positives) than its fixed-budget twin — shorter
/// streams are admissible only because they stopped at the CI target.
/// Early stopping must also engage (decisions + cancellations) and make
/// the run strictly cheaper.
#[test]
fn aa_false_positives_stay_low_across_provider_profiles() {
    let analyzer = Analyzer::native();
    for (i, profile) in ["aws-lambda", "gcp-cloud-functions", "azure-functions"]
        .iter()
        .enumerate()
    {
        let platform = profile_by_name(profile).expect("registered profile").config();
        let sut = small_sut();
        let suite = generate(&sut);
        let exp = ExperimentConfig {
            parallelism: 12,
            seed: 0xAA5E_ED00 + i as u64,
            ..ExperimentConfig::default()
        };
        let cfg = live_cfg(&exp, &analyzer);
        let seed = exp.seed ^ ANALYSIS_SEED_XOR;

        let fixed = run_experiment(&suite, &sut, &platform, &exp, (Version::V1, Version::V1));
        let (live_run, live) =
            run_experiment_live(&suite, &sut, &platform, &exp, (Version::V1, Version::V1), &cfg);

        let fp_fixed = analyzer
            .analyze("aa-fixed", &fixed.measurements, seed)
            .expect("analyze fixed")
            .change_count();
        let fp_live = analyzer
            .analyze("aa-live", &live_run.measurements, seed)
            .expect("analyze live")
            .change_count();
        // Duet pairing shares per-call noise between the two (identical)
        // versions, so A/A relative differences sit tightly around zero.
        assert!(fp_fixed <= 1, "{profile}: fixed A/A reported {fp_fixed} changes");
        assert!(
            fp_live <= fp_fixed + 1,
            "{profile}: live A/A inflates false positives ({fp_live} vs {fp_fixed})"
        );

        // A/A streams are the easiest to decide: early stopping must
        // engage and pay off on every provider calibration.
        assert!(live.decided > 0, "{profile}: nothing decided");
        assert!(live.calls_canceled > 0, "{profile}: nothing canceled");
        assert!(
            live_run.calls_total < fixed.calls_total,
            "{profile}: live {} vs fixed {} calls",
            live_run.calls_total,
            fixed.calls_total
        );
        assert!(
            live_run.cost_usd < fixed.cost_usd,
            "{profile}: live must be strictly cheaper"
        );
    }
}
