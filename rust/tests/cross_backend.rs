//! Cross-backend equivalence: the XLA-artifact analyzer and the native
//! Rust analyzer must produce the *same verdicts* (and near-identical CI
//! numbers) for the same measurements and seed — the key guarantee that
//! lets the native engine serve as the artifact's oracle and perf
//! baseline.
//!
//! Requires `make artifacts` (skips with a notice otherwise).

use elastibench::config::SutConfig;
use elastibench::exp::{baseline, Workbench};
use elastibench::stats::{Analyzer, Measurements};
use elastibench::util::Rng;

fn xla_analyzer_or_skip() -> Option<Analyzer> {
    match Analyzer::xla(&elastibench::artifacts_dir()) {
        Ok(a) => Some(a),
        Err(e) => {
            eprintln!("SKIP: {e:#} — run `make artifacts` first");
            None
        }
    }
}

fn synth_measurements(count: usize, seed: u64) -> Vec<Measurements> {
    let rng = Rng::new(seed);
    (0..count)
        .map(|i| {
            let mut r = rng.fork(i as u64);
            let n = 10 + r.below_usize(36);
            let shift = 1.0 + r.normal_ms(0.0, 0.05);
            Measurements {
                name: format!("Benchmark{i}"),
                v1: (0..n).map(|_| r.lognormal(3.0, 0.2)).collect(),
                v2: (0..n).map(|_| r.lognormal(3.0, 0.2) * shift).collect(),
            }
        })
        .collect()
}

#[test]
fn same_verdicts_and_cis_small_batch() {
    let Some(xla) = xla_analyzer_or_skip() else { return };
    let native = Analyzer::native();
    let ms = synth_measurements(7, 0xC0FFEE);
    let a = xla.analyze("x", &ms, 99).expect("xla analyze");
    let b = native.analyze("n", &ms, 99).expect("native analyze");
    assert_eq!(a.verdicts.len(), b.verdicts.len());
    for (x, n) in a.verdicts.iter().zip(&b.verdicts) {
        assert_eq!(x.name, n.name);
        assert_eq!(x.change, n.change, "{}: {:?} vs {:?}", x.name, x.output, n.output);
        let close = |p: f32, q: f32| (p - q).abs() <= 1e-3 + 1e-4 * p.abs().max(q.abs());
        assert!(close(x.output.ci_lo_pct, n.output.ci_lo_pct), "{}", x.name);
        assert!(close(x.output.ci_hi_pct, n.output.ci_hi_pct), "{}", x.name);
        assert!(close(x.output.boot_median_pct, n.output.boot_median_pct), "{}", x.name);
    }
}

#[test]
fn same_verdicts_full_suite_chunked() {
    // More benchmarks than any artifact's batch capacity: exercises the
    // chunking path.
    let Some(xla) = xla_analyzer_or_skip() else { return };
    let native = Analyzer::native();
    let ms = synth_measurements(150, 0xFEED);
    let a = xla.analyze("x", &ms, 3).expect("xla analyze");
    let b = native.analyze("n", &ms, 3).expect("native analyze");
    assert_eq!(a.verdicts.len(), 150);
    let mismatches = a
        .verdicts
        .iter()
        .zip(&b.verdicts)
        .filter(|(x, n)| x.change != n.change)
        .count();
    assert_eq!(mismatches, 0, "all verdicts must agree across backends");
}

#[test]
fn experiment_analysis_matches_across_backends() {
    let Some(xla) = xla_analyzer_or_skip() else { return };
    // Run the same (small) experiment measurements through both.
    let mut wb = Workbench::with_sut(SutConfig {
        benchmark_count: 12,
        true_changes: 4,
        faas_incompatible: 2,
        slow_setup: 1,
        ..SutConfig::default()
    });
    let native_result = baseline(&wb).expect("native baseline");
    wb.analyzer = xla;
    let xla_result = baseline(&wb).expect("xla baseline");
    assert_eq!(
        native_result.analysis.verdicts.len(),
        xla_result.analysis.verdicts.len()
    );
    for (n, x) in native_result
        .analysis
        .verdicts
        .iter()
        .zip(&xla_result.analysis.verdicts)
    {
        assert_eq!(n.change, x.change, "{}", n.name);
    }
    // The run reports themselves must be identical (same seed, analysis
    // backend does not influence the simulation).
    assert_eq!(native_result.report.wall_s, xla_result.report.wall_s);
    assert_eq!(native_result.report.cost_usd, xla_result.report.cost_usd);
}

#[test]
fn wide_lane_sweep_geometry_works_on_xla() {
    let Some(xla) = xla_analyzer_or_skip() else { return };
    // >64 results per benchmark forces the 256-lane artifact.
    let mut rng = Rng::new(5);
    let ms: Vec<Measurements> = (0..5)
        .map(|i| Measurements {
            name: format!("Wide{i}"),
            v1: (0..135).map(|_| rng.lognormal(0.0, 0.1)).collect(),
            v2: (0..135).map(|_| rng.lognormal(0.0, 0.1) * 1.08).collect(),
        })
        .collect();
    let a = xla.analyze("wide", &ms, 11).expect("xla wide analyze");
    assert_eq!(a.verdicts.len(), 5);
    assert!(a.verdicts.iter().all(|v| v.change.is_change()));
}
