//! Integration test: the AOT artifacts load, compile and agree with the
//! Python reference semantics (re-implemented natively in `stats`).
//!
//! Requires `make artifacts` to have run (skipped with a message if not).

use elastibench::runtime::{AnalysisEngine, Manifest};
use elastibench::util::Rng;

fn manifest_or_skip() -> Option<Manifest> {
    let dir = elastibench::artifacts_dir();
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP: {e:#} — run `make artifacts` first");
            None
        }
    }
}

#[test]
fn artifact_loads_and_detects_known_change() {
    let Some(manifest) = manifest_or_skip() else {
        return;
    };
    let info = manifest.select(4, 45).expect("variant for 4x45");
    let engine = AnalysisEngine::load(&manifest.path_of(info), info.m, info.b, info.n)
        .expect("compile artifact");

    let (m, b, n) = (info.m, info.b, info.n);
    let mut rng = Rng::new(0xE1A5_71BE);
    // Benchmark 0: v2 is ~10% slower (clear change).
    // Benchmark 1: identical distributions (no change).
    // Benchmark 2: v2 is ~20% faster (clear improvement).
    // Remaining rows: padding.
    let mut v1 = vec![1.0f32; m * n];
    let mut v2 = vec![1.0f32; m * n];
    let mut n_valid = vec![1i32; m];
    for row in 0..3 {
        n_valid[row] = 45;
        for j in 0..45 {
            let base = rng.lognormal(0.0, 0.02) as f32;
            let noise2 = rng.lognormal(0.0, 0.02) as f32;
            v1[row * n + j] = base;
            v2[row * n + j] = match row {
                0 => noise2 * 1.10,
                1 => noise2,
                _ => noise2 * 0.80,
            };
        }
    }
    let mut idx = vec![0i32; b * n];
    rng.fill_index_bits(&mut idx);

    let out = engine.analyze(&v1, &v2, &n_valid, &idx).expect("analyze");
    assert_eq!(out.len(), m);

    assert!(out[0].is_change(), "10% regression must be detected: {:?}", out[0]);
    assert_eq!(out[0].direction(), 1);
    assert!((out[0].boot_median_pct - 10.0).abs() < 3.0, "{:?}", out[0]);

    assert!(!out[1].is_change(), "A/A row must not flag: {:?}", out[1]);

    assert!(out[2].is_change(), "20% improvement must be detected: {:?}", out[2]);
    assert_eq!(out[2].direction(), -1);
    assert!((out[2].boot_median_pct + 20.0).abs() < 3.0, "{:?}", out[2]);

    // CI ordering invariant.
    for o in &out {
        assert!(o.ci_lo_pct <= o.boot_median_pct && o.boot_median_pct <= o.ci_hi_pct);
    }
}

#[test]
fn artifact_matches_native_engine() {
    let Some(manifest) = manifest_or_skip() else {
        return;
    };
    let info = manifest.select(8, 45).expect("variant");
    let engine = AnalysisEngine::load(&manifest.path_of(info), info.m, info.b, info.n)
        .expect("compile artifact");
    let (m, b, n) = (info.m, info.b, info.n);

    let mut rng = Rng::new(77);
    let mut v1 = vec![1.0f32; m * n];
    let mut v2 = vec![1.0f32; m * n];
    let mut n_valid = vec![1i32; m];
    for row in 0..m {
        let nv = 10 + rng.below_usize(36); // 10..=45
        n_valid[row] = nv as i32;
        for j in 0..nv {
            v1[row * n + j] = rng.lognormal(0.0, 0.3) as f32;
            v2[row * n + j] = rng.lognormal(0.05, 0.3) as f32;
        }
    }
    let mut idx = vec![0i32; b * n];
    rng.fill_index_bits(&mut idx);

    let xla_out = engine.analyze(&v1, &v2, &n_valid, &idx).expect("xla");
    let native_out = elastibench::stats::bootstrap_native(
        &v1, &v2, &n_valid, &idx, m, b, n, manifest.alpha,
    );
    assert_eq!(xla_out.len(), native_out.len());
    for (i, (x, r)) in xla_out.iter().zip(&native_out).enumerate() {
        let close = |a: f32, b: f32| (a - b).abs() <= 1e-3 + 1e-4 * a.abs().max(b.abs());
        assert!(close(x.ci_lo_pct, r.ci_lo_pct), "row {i}: {x:?} vs {r:?}");
        assert!(close(x.boot_median_pct, r.boot_median_pct), "row {i}: {x:?} vs {r:?}");
        assert!(close(x.ci_hi_pct, r.ci_hi_pct), "row {i}: {x:?} vs {r:?}");
        assert!(close(x.median_v1, r.median_v1), "row {i}: {x:?} vs {r:?}");
        assert!(close(x.median_v2, r.median_v2), "row {i}: {x:?} vs {r:?}");
        assert!(close(x.point_pct, r.point_pct), "row {i}: {x:?} vs {r:?}");
    }
}
