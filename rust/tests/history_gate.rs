//! End-to-end continuous-benchmarking gate: record real scenario runs
//! into a store, inject a per-benchmark regression in the newest run,
//! and assert the gate trips with a nonzero CLI exit code — and that a
//! single noisy run inside the baseline window does *not* trip it.
//!
//! Everything is deterministic: commits are strings set on the report,
//! timestamps are caller-provided, and the scenario runs from pinned
//! seeds — the same flow gives byte-identical gate output every time.

use elastibench::cli::{self, Args};
use elastibench::history::{evaluate, GatePolicy, GateReason, HistoryStore, Timeline};
use elastibench::runtime::AnalysisOutput;
use elastibench::scenario::{catalog_entry, run_scenario, ScenarioReport};
use elastibench::stats::{Analyzer, ChangeKind};

/// A shrunk quick-smoke run (seconds of host time, pinned seeds).
fn tiny_report() -> ScenarioReport {
    let mut sc = catalog_entry("quick-smoke").unwrap();
    sc.sut.benchmark_count = 6;
    sc.sut.true_changes = 1;
    sc.sut.faas_incompatible = 1;
    sc.sut.slow_setup = 0;
    sc.exp.calls_per_benchmark = 6;
    sc.exp.parallelism = 8;
    run_scenario(&sc, &Analyzer::native()).unwrap()
}

fn temp_store(tag: &str) -> HistoryStore {
    let dir = std::env::temp_dir().join(format!("elastibench_e2e_gate_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    HistoryStore::open(dir)
}

/// Index of a benchmark the run classified as NoChange — the victim the
/// tests inject a regression into.
fn clean_benchmark(report: &ScenarioReport) -> usize {
    report
        .analysis
        .verdicts
        .iter()
        .position(|v| v.change == ChangeKind::NoChange)
        .expect("quick-smoke has a clean benchmark")
}

/// Overwrite one verdict with a CI-backed +10% regression.
fn inject_regression(report: &mut ScenarioReport, idx: usize) {
    let v = &mut report.analysis.verdicts[idx];
    v.output = AnalysisOutput {
        ci_lo_pct: 8.0,
        boot_median_pct: 10.0,
        ci_hi_pct: 12.0,
        median_v1: v.output.median_v1,
        median_v2: v.output.median_v1 * 1.10,
        point_pct: 10.0,
    };
    v.change = ChangeKind::Regression;
}

fn gate_exit_code(store: &HistoryStore) -> i32 {
    gate_exit_for(store, "quick-smoke")
}

fn gate_exit_for(store: &HistoryStore, scenario: &str) -> i32 {
    let args = Args::parse(
        [
            "history".to_string(),
            "gate".to_string(),
            scenario.to_string(),
            "--store".to_string(),
            store.root().display().to_string(),
        ],
    )
    .unwrap();
    cli::run(args).unwrap()
}

#[test]
fn injected_regression_trips_the_gate_with_exit_code_1() {
    let store = temp_store("trip");
    let mut report = tiny_report();
    for commit in ["c1", "c2", "c3"] {
        report.commit = commit.to_string();
        store.record(&report, commit).unwrap();
    }
    let idx = clean_benchmark(&report);
    let victim = report.analysis.verdicts[idx].name.clone();
    report.commit = "c4".to_string();
    inject_regression(&mut report, idx);
    store.record(&report, "c4").unwrap();

    let tl = Timeline::load(&store, "quick-smoke").unwrap();
    assert_eq!(tl.len(), 4);
    let out = evaluate(&tl, &GatePolicy::default()).unwrap();
    assert!(out.skipped.is_none());
    assert!(!out.passed(), "injected regression must trip the gate");
    assert_eq!(out.findings.len(), 1, "{:?}", out.findings);
    let f = &out.findings[0];
    assert_eq!(f.benchmark, victim);
    assert_eq!(f.reason, GateReason::ThresholdExceeded);
    assert!(f.delta_pct > 5.0, "{}", f.delta_pct);
    assert_eq!(out.newest_commit, "c4");
    assert_eq!(out.baseline_runs.len(), 3);

    // Same store, same policy -> byte-identical outcome (no wall clock,
    // no RNG anywhere in the gate path).
    let again = evaluate(&Timeline::load(&store, "quick-smoke").unwrap(), &GatePolicy::default())
        .unwrap();
    assert_eq!(format!("{out:?}"), format!("{again:?}"));

    // The CLI surfaces the failure as a nonzero exit code for CI.
    assert_eq!(gate_exit_code(&store), 1);
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn single_noisy_baseline_run_does_not_trip_the_gate() {
    let store = temp_store("noise");
    let mut report = tiny_report();
    let idx = clean_benchmark(&report);
    let original = report.analysis.verdicts[idx].clone();

    report.commit = "c1".to_string();
    store.record(&report, "c1").unwrap();
    // c2 is a one-off noisy run: the same benchmark spikes to +10%...
    report.commit = "c2".to_string();
    inject_regression(&mut report, idx);
    store.record(&report, "c2").unwrap();
    // ...and settles back for c3 and the gated newest run c4.
    report.analysis.verdicts[idx] = original;
    for commit in ["c3", "c4"] {
        report.commit = commit.to_string();
        store.record(&report, commit).unwrap();
    }

    let tl = Timeline::load(&store, "quick-smoke").unwrap();
    let out = evaluate(&tl, &GatePolicy::default()).unwrap();
    assert!(out.skipped.is_none());
    assert!(
        out.passed(),
        "a single outlier inside the baseline window tripped the gate: {:?}",
        out.findings
    );
    assert_eq!(gate_exit_code(&store), 0);
    let _ = std::fs::remove_dir_all(store.root());
}

/// A shrunk quick-smoke run executed under a non-default strategy, named
/// like a `[matrix] strategy` variant so it gets its own store timeline.
fn tiny_variant_report(strategy: elastibench::coordinator::StrategyKind) -> ScenarioReport {
    let mut sc = catalog_entry("quick-smoke").unwrap();
    sc.sut.benchmark_count = 6;
    sc.sut.true_changes = 1;
    sc.sut.faas_incompatible = 1;
    sc.sut.slow_setup = 0;
    sc.exp.calls_per_benchmark = 6;
    sc.exp.parallelism = 8;
    sc.strategy = strategy;
    sc.name = format!("quick-smoke@strategy={}", strategy.as_str());
    sc.exp.label = sc.name.clone();
    run_scenario(&sc, &Analyzer::native()).unwrap()
}

#[test]
fn strategy_metadata_roundtrips_losslessly_through_the_store() {
    use elastibench::coordinator::StrategyKind;
    use elastibench::history::stored_run_to_json;
    use elastibench::report::scenario_report_to_json;

    let store = temp_store("strategy_meta");
    let report = tiny_variant_report(StrategyKind::Rmit);
    let exported = scenario_report_to_json(&report).to_string();
    assert!(exported.contains("\"strategy\":\"rmit\""), "export carries the strategy");

    let meta = store.record(&report, "t-1").unwrap();
    let loaded = store.load(&report.scenario.name, &meta.run_id).unwrap();
    assert_eq!(loaded.metadata.strategy, "rmit");
    assert_eq!(
        stored_run_to_json(&loaded).to_string(),
        exported,
        "record -> load -> re-export must preserve metadata.strategy byte-identically"
    );
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn strategy_variants_gate_independently() {
    use elastibench::coordinator::StrategyKind;

    // Two timelines in one store: the plain duet scenario and its
    // pinned-duet strategy variant. A regression recorded on the variant
    // must trip ONLY the variant's gate — the duet timeline stays green.
    let store = temp_store("strategy_gate");

    let mut duet = tiny_report();
    for commit in ["d1", "d2", "d3", "d4"] {
        duet.commit = commit.to_string();
        store.record(&duet, commit).unwrap();
    }

    let mut pinned = tiny_variant_report(StrategyKind::DuetPinned);
    let pinned_name = pinned.scenario.name.clone();
    for commit in ["p1", "p2", "p3"] {
        pinned.commit = commit.to_string();
        store.record(&pinned, commit).unwrap();
    }
    let idx = clean_benchmark(&pinned);
    inject_regression(&mut pinned, idx);
    pinned.commit = "p4".to_string();
    store.record(&pinned, "p4").unwrap();

    assert_eq!(
        store.scenarios().unwrap(),
        vec!["quick-smoke".to_string(), pinned_name.clone()],
        "variants keep separate timelines"
    );

    let duet_out =
        evaluate(&Timeline::load(&store, "quick-smoke").unwrap(), &GatePolicy::default()).unwrap();
    assert!(duet_out.passed(), "duet timeline must stay green: {:?}", duet_out.findings);
    let pinned_out =
        evaluate(&Timeline::load(&store, &pinned_name).unwrap(), &GatePolicy::default()).unwrap();
    assert!(!pinned_out.passed(), "variant regression must trip its own gate");

    assert_eq!(gate_exit_code(&store), 0);
    assert_eq!(gate_exit_for(&store, &pinned_name), 1);
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn store_grows_append_only_and_survives_reload() {
    let store = temp_store("append");
    let mut report = tiny_report();
    for (i, commit) in ["a1", "a2", "a3"].iter().enumerate() {
        report.commit = commit.to_string();
        let meta = store.record(&report, &format!("build-{i}")).unwrap();
        assert_eq!(meta.run_id, format!("{:04}-{commit}", i + 1));
        // Re-opening the store sees exactly the runs recorded so far.
        let reopened = HistoryStore::open(store.root());
        assert_eq!(reopened.runs("quick-smoke").unwrap().len(), i + 1);
    }
    let runs = store.runs("quick-smoke").unwrap();
    assert_eq!(runs.len(), 3);
    assert_eq!(runs[1].timestamp, "build-1");
    assert_eq!(runs[2].commit, "a3");
    let loaded = store.load("quick-smoke", &runs[2].run_id).unwrap();
    assert_eq!(loaded.metadata.commit, "a3");
    assert_eq!(
        loaded.analysis.verdicts.len(),
        report.analysis.verdicts.len()
    );
    let _ = std::fs::remove_dir_all(store.root());
}
