//! Differential tests over the two storage backends: the filesystem
//! layout (the original, kept verbatim — the oracle) and the compact
//! segment-file layout, plus the `history compact` migration, the
//! torn-index tolerance of the fs reader, paged-vs-load_all byte
//! identity, and concurrent reader/writer safety on both backends.

use elastibench::cli::{self, Args};
use elastibench::history::{
    evaluate, evaluate_latest, stored_run_to_json, BackendKind, GatePolicy, HistoryStore,
    Timeline, TimelineEntry,
};
use elastibench::runtime::AnalysisOutput;
use elastibench::scenario::{catalog_entry, run_scenario, ScenarioReport};
use elastibench::stats::{Analyzer, ChangeKind};
use std::sync::atomic::{AtomicBool, Ordering};

/// A shrunk quick-smoke run (seconds of host time, pinned seeds).
fn tiny_report() -> ScenarioReport {
    let mut sc = catalog_entry("quick-smoke").unwrap();
    sc.sut.benchmark_count = 6;
    sc.sut.true_changes = 1;
    sc.sut.faas_incompatible = 1;
    sc.sut.slow_setup = 0;
    sc.exp.calls_per_benchmark = 6;
    sc.exp.parallelism = 8;
    run_scenario(&sc, &Analyzer::native()).unwrap()
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("elastibench_backends_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Overwrite one NoChange verdict with a CI-backed +10% regression.
fn inject_regression(report: &mut ScenarioReport) {
    let idx = report
        .analysis
        .verdicts
        .iter()
        .position(|v| v.change == ChangeKind::NoChange)
        .expect("quick-smoke has a clean benchmark");
    let v = &mut report.analysis.verdicts[idx];
    v.output = AnalysisOutput {
        ci_lo_pct: 8.0,
        boot_median_pct: 10.0,
        ci_hi_pct: 12.0,
        median_v1: v.output.median_v1,
        median_v2: v.output.median_v1 * 1.10,
        point_pct: 10.0,
    };
    v.change = ChangeKind::Regression;
}

#[test]
fn compact_backend_is_field_identical_to_fs() {
    let fs = HistoryStore::open_fs(temp_dir("diff_fs"));
    let compact = HistoryStore::open_compact(temp_dir("diff_compact"));
    assert_eq!(fs.backend_kind(), BackendKind::Fs);
    assert_eq!(compact.backend_kind(), BackendKind::Compact);

    let mut report = tiny_report();
    for commit in ["c1", "c2", "c3", "c4"] {
        report.commit = commit.to_string();
        let a = fs.record(&report, commit).unwrap();
        let b = compact.record(&report, commit).unwrap();
        assert_eq!(a, b, "record must return identical RunMeta on both backends");
    }

    assert_eq!(fs.scenarios().unwrap(), compact.scenarios().unwrap());
    assert_eq!(
        fs.latest_seq("quick-smoke").unwrap(),
        compact.latest_seq("quick-smoke").unwrap()
    );
    assert_eq!(
        fs.runs("quick-smoke").unwrap(),
        compact.runs("quick-smoke").unwrap()
    );
    // Paged slices agree too, including the total and a past-end page.
    for (offset, limit) in [(0, 2), (1, 2), (3, 10), (99, 5), (0, 0)] {
        assert_eq!(
            fs.runs_page("quick-smoke", offset, limit).unwrap(),
            compact.runs_page("quick-smoke", offset, limit).unwrap(),
            "page offset={offset} limit={limit}"
        );
    }
    // Stored runs come back field-for-field identical (compare through
    // the lossless re-export) and documents byte-for-byte.
    for meta in fs.runs("quick-smoke").unwrap() {
        let a = fs.load("quick-smoke", &meta.run_id).unwrap();
        let b = compact.load("quick-smoke", &meta.run_id).unwrap();
        assert_eq!(
            stored_run_to_json(&a).to_string(),
            stored_run_to_json(&b).to_string()
        );
        assert_eq!(
            fs.load_doc("quick-smoke", &meta.run_id).unwrap(),
            compact.load_doc("quick-smoke", &meta.run_id).unwrap()
        );
    }
    // Both reject what the other rejects.
    assert!(compact.runs("../evil").is_err());
    assert!(compact.load("quick-smoke", "0001-wrong-commit").is_err());
    assert!(compact.load("quick-smoke", "9999-c1").is_err());

    let _ = std::fs::remove_dir_all(fs.root());
    let _ = std::fs::remove_dir_all(compact.root());
}

#[test]
fn history_compact_migration_round_trips_and_gates_identically() {
    let src_dir = temp_dir("migrate_src");
    let src = HistoryStore::open(&src_dir);
    let mut report = tiny_report();
    for commit in ["m1", "m2", "m3"] {
        report.commit = commit.to_string();
        src.record(&report, commit).unwrap();
    }
    report.commit = "m4".to_string();
    inject_regression(&mut report);
    src.record(&report, "m4").unwrap();

    // Migrate through the CLI surface.
    let dest_dir = temp_dir("migrate_dest");
    let code = cli::run(
        Args::parse(
            [
                "history",
                "compact",
                "--store",
                src_dir.to_str().unwrap(),
                "--dest",
                dest_dir.to_str().unwrap(),
            ]
            .map(String::from),
        )
        .unwrap(),
    )
    .unwrap();
    assert_eq!(code, 0);

    // `open` auto-detects the compact layout from the marker.
    let dest = HistoryStore::open(&dest_dir);
    assert_eq!(dest.backend_kind(), BackendKind::Compact);
    assert_eq!(src.runs("quick-smoke").unwrap(), dest.runs("quick-smoke").unwrap());
    for meta in src.runs("quick-smoke").unwrap() {
        assert_eq!(
            src.load_doc("quick-smoke", &meta.run_id).unwrap(),
            dest.load_doc("quick-smoke", &meta.run_id).unwrap(),
            "migration must preserve document bytes"
        );
    }
    // The gate reaches the same verdict on both layouts.
    let policy = GatePolicy::default();
    let a = evaluate_latest(&src, "quick-smoke", &policy).unwrap();
    let b = evaluate_latest(&dest, "quick-smoke", &policy).unwrap();
    assert!(!a.passed(), "injected regression must trip the gate");
    assert_eq!(format!("{a:?}"), format!("{b:?}"));

    // Migration never merges into an existing store.
    let err = elastibench::history::compact::migrate(&src, &dest_dir).unwrap_err();
    assert!(err.to_string().contains("not empty"), "{err}");

    let _ = std::fs::remove_dir_all(&src_dir);
    let _ = std::fs::remove_dir_all(&dest_dir);
}

#[test]
fn truncated_final_index_line_is_tolerated_and_healed() {
    let dir = temp_dir("torn");
    let store = HistoryStore::open(&dir);
    let mut report = tiny_report();
    for commit in ["t1", "t2", "t3"] {
        report.commit = commit.to_string();
        store.record(&report, commit).unwrap();
    }
    let index = dir.join("quick-smoke").join("index.jsonl");

    // A crash mid-append under the old writer leaves half a line behind.
    let intact = std::fs::read_to_string(&index).unwrap();
    std::fs::write(&index, format!("{intact}{{\"run_id\":\"0004-t4\",\"scen")).unwrap();
    let runs = store.runs("quick-smoke").unwrap();
    assert_eq!(runs.len(), 3, "torn final line is dropped, not fatal");
    assert_eq!(runs[2].run_id, "0003-t3");

    // The next record rebuilds the index atomically: the debris is gone
    // and the new run is appended cleanly.
    report.commit = "t4".to_string();
    let meta = store.record(&report, "t4").unwrap();
    assert_eq!(meta.run_id, "0004-t4");
    let healed = std::fs::read_to_string(&index).unwrap();
    assert_eq!(healed.lines().count(), 4);
    assert!(healed.lines().all(|l| l.starts_with('{') && l.ends_with('}')));
    assert_eq!(store.runs("quick-smoke").unwrap().len(), 4);

    // Interior corruption is NOT waved through: that is data loss, not
    // append debris.
    let mut lines: Vec<String> = healed.lines().map(String::from).collect();
    lines[1] = "{\"run_id\":\"0002-t2\",\"scen".to_string();
    std::fs::write(&index, format!("{}\n", lines.join("\n"))).unwrap();
    assert!(store.runs("quick-smoke").is_err());

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn paged_loading_matches_the_load_all_oracle() {
    let dir = temp_dir("paged_oracle");
    let store = HistoryStore::open(&dir);
    let mut report = tiny_report();
    for commit in ["o1", "o2", "o3", "o4", "o5"] {
        report.commit = commit.to_string();
        store.record(&report, commit).unwrap();
    }
    report.commit = "o6".to_string();
    inject_regression(&mut report);
    store.record(&report, "o6").unwrap();

    // Oracle: the pre-refactor full-archive path.
    let oracle_entries: Vec<TimelineEntry> = store
        .load_all("quick-smoke")
        .unwrap()
        .into_iter()
        .map(|(meta, run)| TimelineEntry { meta, run })
        .collect();
    let oracle = Timeline {
        scenario: "quick-smoke".to_string(),
        entries: oracle_entries,
    };

    // Paged full load is byte-identical to the oracle.
    let paged = Timeline::load(&store, "quick-smoke").unwrap();
    assert_eq!(format!("{paged:?}"), format!("{oracle:?}"));

    // Paged tail load equals the oracle's tail.
    let policy = GatePolicy::default();
    let tail = Timeline::load_last(&store, "quick-smoke", policy.window + 1).unwrap();
    let oracle_tail = Timeline {
        scenario: oracle.scenario.clone(),
        entries: oracle.entries[oracle.entries.len() - (policy.window + 1)..].to_vec(),
    };
    assert_eq!(format!("{tail:?}"), format!("{oracle_tail:?}"));

    // And the gate over the paged tail equals the gate over the oracle
    // tail — the refactor changed how runs are fetched, not the verdict.
    let a = evaluate(&oracle_tail, &policy).unwrap();
    let b = evaluate_latest(&store, "quick-smoke", &policy).unwrap();
    assert!(!b.passed());
    assert_eq!(format!("{a:?}"), format!("{b:?}"));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn list_pagination_flags_page_the_listing() {
    let dir = temp_dir("list_flags");
    let store = HistoryStore::open(&dir);
    let mut report = tiny_report();
    for commit in ["p1", "p2", "p3"] {
        report.commit = commit.to_string();
        store.record(&report, commit).unwrap();
    }
    let run = |extra: &[&str]| -> anyhow::Result<i32> {
        let mut argv = vec![
            "history".to_string(),
            "list".to_string(),
            "quick-smoke".to_string(),
            "--store".to_string(),
            dir.display().to_string(),
        ];
        argv.extend(extra.iter().map(|s| s.to_string()));
        cli::run(Args::parse(argv).unwrap())
    };
    assert_eq!(run(&[]).unwrap(), 0);
    assert_eq!(run(&["--limit", "2"]).unwrap(), 0);
    assert_eq!(run(&["--limit", "2", "--page", "2"]).unwrap(), 0);
    assert_eq!(run(&["--limit", "2", "--json"]).unwrap(), 0);
    assert!(run(&["--page", "2"]).is_err(), "--page requires --limit");
    assert!(run(&["--limit", "0"]).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

/// N readers hammer `runs_page`/`load` while one writer records:
/// every read must succeed (no torn reads) and the observed totals and
/// newest seqs must be monotone.
fn hammer(store: &HistoryStore, tag: &str) {
    let mut report = tiny_report();
    report.commit = "w0".to_string();
    store.record(&report, "w0").unwrap();

    const WRITES: usize = 12;
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        for reader in 0..3 {
            let store = store.clone();
            let stop = &stop;
            scope.spawn(move || {
                let mut last_total = 0usize;
                let mut last_seq = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let page = store.runs_page("quick-smoke", 0, usize::MAX).unwrap();
                    assert!(
                        page.total >= last_total,
                        "[{tag} reader {reader}] total shrank: {} -> {}",
                        last_total,
                        page.total
                    );
                    last_total = page.total;
                    let newest = page.runs.last().expect("at least the seed run");
                    let seq: usize = newest.run_id.split('-').next().unwrap().parse().unwrap();
                    assert!(
                        seq >= last_seq,
                        "[{tag} reader {reader}] newest seq went backwards"
                    );
                    last_seq = seq;
                    // Any listed run must load fully — a torn read here
                    // would fail the parse or the schema check.
                    let run = store.load("quick-smoke", &newest.run_id).unwrap();
                    assert_eq!(run.scenario.name, "quick-smoke");
                }
            });
        }
        for i in 1..=WRITES {
            report.commit = format!("w{i}");
            store.record(&report, &format!("w{i}")).unwrap();
        }
        stop.store(true, Ordering::Relaxed);
    });
    assert_eq!(store.runs_total("quick-smoke").unwrap(), WRITES + 1);
    assert_eq!(store.latest_seq("quick-smoke").unwrap(), WRITES + 1);
}

#[test]
fn concurrent_readers_and_writer_fs_backend() {
    let dir = temp_dir("concurrent_fs");
    hammer(&HistoryStore::open_fs(&dir), "fs");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_readers_and_writer_compact_backend() {
    let dir = temp_dir("concurrent_compact");
    hammer(&HistoryStore::open_compact(&dir), "compact");
    let _ = std::fs::remove_dir_all(&dir);
}
