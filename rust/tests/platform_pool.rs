//! Differential suite for the slot-map instance pool: the production
//! [`FaasPlatform`] must match the retired O(N)-scan
//! [`ReferencePlatform`] observable-for-observable — placements, cold
//! starts, billing, env-factor draws, stats — across seeded random
//! workloads, including reaping.
//!
//! One deliberate carve-out: the reference pool's `Vec::retain` reap
//! compacts the instance table and silently redirects in-flight
//! `Placement` handles (see `faas::platform_reference` module docs).
//! Workloads here therefore quiesce (release everything) before any
//! reap-triggering time jump — the domain where the reference is
//! correct and agreement must be exact. The
//! `reap_while_in_flight_regression` test pins the bug itself down: it
//! fails against the reference pool and passes against the slot map.
//!
//! Tie-break caveat (documented per the acceptance criteria): when two
//! instances go idle at the *bit-identical* time, the reference's
//! `min_by` scan picks the first in creation order while the FIFO deque
//! picks the first released. Event times are continuous draws, so the
//! seeded workloads here never produce such ties; a workload engineered
//! to tie would be the one place the two pools may deterministically
//! differ.

use elastibench::config::{ExperimentConfig, PlatformConfig, SutConfig};
use elastibench::coordinator::{run_experiment, run_experiment_reference};
use elastibench::faas::{FaasPlatform, InstancePool, Placement, ReferencePlatform};
use elastibench::sut::{generate, Version};
use elastibench::util::Rng;

fn deploy_both(cfg: &PlatformConfig, seed: u64) -> (FaasPlatform, ReferencePlatform) {
    (
        FaasPlatform::deploy(cfg, 1700.0, 2048, 12.0, seed),
        ReferencePlatform::deploy(cfg, 1700.0, 2048, 12.0, seed),
    )
}

/// Drive both pools through one seeded random workload in lockstep,
/// comparing every observable after every operation.
fn lockstep_workload(cfg: &PlatformConfig, seed: u64, steps: usize) {
    let (mut a, mut b) = deploy_both(cfg, 0xD1FF ^ seed);
    let mut rng = Rng::new(seed);
    let mut t = 0.0_f64;
    let mut held: Vec<(Placement, Placement)> = Vec::new();
    let mut reap_phases = 0usize;

    for step in 0..steps {
        // Quiesce every ~48 steps: release everything, jump past the
        // keepalive window, and let the next acquire reap the whole
        // idle fleet. In-phase drift stays far below keepalive_s, so no
        // instance ever expires while a handle is in flight (the
        // reference's broken domain, see module docs).
        if step % 48 == 47 {
            while let Some((pa, pb)) = held.pop() {
                t += rng.f64() * 0.2;
                let billed = rng.f64() * 4.0;
                a.release(pa.instance, t, billed);
                b.release(pb.instance, t, billed);
            }
            t += cfg.keepalive_s + 1.0 + rng.f64() * cfg.keepalive_s;
            reap_phases += 1;
        }

        t += rng.f64() * 0.4;
        match rng.below(10) {
            0..=3 => {
                let pa = a.acquire(t);
                let pb = b.acquire(t);
                assert_eq!(pa.is_some(), pb.is_some(), "step {step}: acquire outcome");
                if let (Some(pa), Some(pb)) = (pa, pb) {
                    assert_eq!(pa.cold, pb.cold, "step {step}: cold flag");
                    assert_eq!(pa.start_at, pb.start_at, "step {step}: start_at");
                    assert_eq!(
                        a.instance_id(pa.instance),
                        b.instance_id(pb.instance),
                        "step {step}: placed on different instances"
                    );
                    assert_eq!(
                        a.cache_warm(pa.instance),
                        b.cache_warm(pb.instance),
                        "step {step}: cache state"
                    );
                    held.push((pa, pb));
                }
            }
            4..=7 if !held.is_empty() => {
                let i = rng.below_usize(held.len());
                let (pa, pb) = held.swap_remove(i);
                let billed = rng.f64() * 4.0;
                a.release(pa.instance, t, billed);
                b.release(pb.instance, t, billed);
            }
            _ if !held.is_empty() => {
                let i = rng.below_usize(held.len());
                let (pa, pb) = held[i];
                assert_eq!(
                    a.env_factor(pa.instance, t),
                    b.env_factor(pb.instance, t),
                    "step {step}: env factor"
                );
            }
            _ => {}
        }

        assert_eq!(a.stats(), b.stats(), "step {step}: stats diverged");
        assert_eq!(a.instance_count(), b.instance_count(), "step {step}");
        assert_eq!(a.cost_usd(), b.cost_usd(), "step {step}: cost");
    }
    assert!(reap_phases > 0, "workload must exercise reaping");
    assert!(a.stats().instances_reaped > 0, "reaping never triggered");
}

#[test]
fn random_workloads_with_reaping_match_reference() {
    let cfg = PlatformConfig {
        keepalive_s: 40.0,
        ..PlatformConfig::default()
    };
    for seed in [1u64, 7, 42, 1234, 99999] {
        lockstep_workload(&cfg, seed, 600);
    }
}

#[test]
fn workloads_match_under_tight_concurrency_limit() {
    // Acquire rejections (the backoff path) must count and bill
    // identically on both pools.
    let cfg = PlatformConfig {
        keepalive_s: 30.0,
        concurrency_limit: 5,
        ..PlatformConfig::default()
    };
    for seed in [3u64, 17, 2718] {
        lockstep_workload(&cfg, seed, 400);
    }
}

#[test]
fn partial_reap_takes_only_the_expired_prefix() {
    // Staggered idle times, then a jump that expires only some: both
    // pools must reap the same subset and reuse the same survivor.
    let cfg = PlatformConfig {
        keepalive_s: 50.0,
        ..PlatformConfig::default()
    };
    let (mut a, mut b) = deploy_both(&cfg, 7);
    let mut placements = Vec::new();
    for i in 0..6 {
        let t = i as f64 * 0.1;
        placements.push((a.acquire(t).unwrap(), b.acquire(t).unwrap()));
    }
    // Release at strongly staggered times: idle since 10, 30, 50, ...
    for (i, (pa, pb)) in placements.iter().enumerate() {
        let t_end = 10.0 + 20.0 * i as f64;
        a.release(pa.instance, t_end, 1.0);
        b.release(pb.instance, t_end, 1.0);
    }
    // At t = 95 exactly the first two (idle since 10 and 30) are past
    // the 50 s keepalive; nothing is in flight, so the reference reaps
    // correctly too.
    let (na, nb) = (a.acquire(95.0).unwrap(), b.acquire(95.0).unwrap());
    assert_eq!(a.stats().instances_reaped, 2);
    assert_eq!(a.stats(), b.stats());
    assert!(!na.cold && !nb.cold, "longest-idle survivor is reused warm");
    assert_eq!(a.instance_id(na.instance), b.instance_id(nb.instance));
    // The survivor reused is the one idle since t = 50 (third released).
    assert_eq!(a.instance_id(na.instance), 2);
}

/// Run the reap-while-in-flight scenario against any pool; returns true
/// when release/billing land on the right instance afterwards.
fn survives_reap_while_in_flight<P: InstancePool>(mut p: P) -> bool {
    let a = p.acquire(0.0).expect("first cold start");
    let b = p.acquire(0.1).expect("second cold start");
    let b_id = p.instance_id(b.instance);
    p.release(a.instance, 1.0, 0.9);
    // keepalive_s = 10: instance a expires at t = 11; this acquire reaps
    // it while b's Placement handle is still held by an in-flight call.
    let c = p.acquire(20.0).expect("cold start after reap");
    assert!(c.cold, "a was reaped, so this must cold-start");
    assert_eq!(p.stats().instances_reaped, 1);
    p.release(b.instance, 21.0, 20.0);
    // Correct pool: b's handle still names b, and the cold newcomer c
    // has not magically finished an invocation.
    p.instance_id(b.instance) == b_id && !p.cache_warm(c.instance)
}

#[test]
fn reap_while_in_flight_regression() {
    let cfg = PlatformConfig {
        keepalive_s: 10.0,
        ..PlatformConfig::default()
    };
    assert!(
        survives_reap_while_in_flight(FaasPlatform::deploy(&cfg, 1700.0, 2048, 12.0, 5)),
        "slot map must keep in-flight handles stable across reaps"
    );
    // The same scenario demonstrably FAILS on the reference pool — its
    // retain() compaction redirects b's handle onto the newcomer. If
    // this assertion ever flips, the reference was fixed and the
    // differential harness can drop its quiesce-before-reap constraint.
    assert!(
        !survives_reap_while_in_flight(ReferencePlatform::deploy(&cfg, 1700.0, 2048, 12.0, 5)),
        "reference pool unexpectedly survived reap-while-in-flight"
    );
}

/// Compare two full experiment reports field by field (RunReport does
/// not derive PartialEq because Measurements doesn't).
fn assert_reports_identical(
    a: &elastibench::coordinator::RunReport,
    b: &elastibench::coordinator::RunReport,
    label: &str,
) {
    assert_eq!(a.wall_s, b.wall_s, "{label}: wall_s");
    assert_eq!(a.invoke_wall_s, b.invoke_wall_s, "{label}: invoke_wall_s");
    assert_eq!(a.cost_usd, b.cost_usd, "{label}: cost_usd");
    assert_eq!(a.calls_total, b.calls_total, "{label}: calls_total");
    assert_eq!(a.calls_ok, b.calls_ok, "{label}: calls_ok");
    assert_eq!(a.failures, b.failures, "{label}: failures");
    assert_eq!(a.platform, b.platform, "{label}: platform stats");
    assert_eq!(a.failed_benchmarks, b.failed_benchmarks, "{label}");
    assert_eq!(a.measurements.len(), b.measurements.len(), "{label}");
    for (ma, mb) in a.measurements.iter().zip(&b.measurements) {
        assert_eq!(ma.name, mb.name, "{label}");
        assert_eq!(ma.v1, mb.v1, "{label}: {} v1 samples", ma.name);
        assert_eq!(ma.v2, mb.v2, "{label}: {} v2 samples", ma.name);
    }
}

#[test]
fn full_experiments_match_reference_invocation_for_invocation() {
    // The identical coordinator loop runs against both pools; every
    // report field must agree bit-for-bit. Since scenario reports are a
    // deterministic function of the RunReport (plus metadata), this is
    // exactly the "shipped scenario reports stay byte-identical"
    // guarantee, exercised across parallelism regimes, A/A mode, crash
    // retries and the concurrency-backoff path.
    let sut = SutConfig {
        benchmark_count: 12,
        true_changes: 3,
        faas_incompatible: 2,
        slow_setup: 1,
        ..SutConfig::default()
    };
    let suite = generate(&sut);

    let cases: Vec<(&str, PlatformConfig, ExperimentConfig, (Version, Version))> = vec![
        (
            "serial",
            PlatformConfig::default(),
            ExperimentConfig {
                calls_per_benchmark: 5,
                parallelism: 1,
                ..ExperimentConfig::default()
            },
            (Version::V1, Version::V2),
        ),
        (
            "parallel-aa",
            PlatformConfig::default(),
            ExperimentConfig {
                calls_per_benchmark: 6,
                parallelism: 40,
                ..ExperimentConfig::default()
            },
            (Version::V1, Version::V1),
        ),
        (
            "crashy",
            PlatformConfig {
                crash_probability: 0.15,
                ..PlatformConfig::default()
            },
            ExperimentConfig {
                calls_per_benchmark: 5,
                parallelism: 20,
                seed: 777,
                ..ExperimentConfig::default()
            },
            (Version::V1, Version::V2),
        ),
        (
            "throttled",
            PlatformConfig {
                concurrency_limit: 8,
                ..PlatformConfig::default()
            },
            ExperimentConfig {
                calls_per_benchmark: 5,
                parallelism: 30,
                seed: 31337,
                ..ExperimentConfig::default()
            },
            (Version::V1, Version::V2),
        ),
    ];
    for (label, plat, exp, versions) in &cases {
        let pooled = run_experiment(&suite, &sut, plat, exp, *versions);
        let reference = run_experiment_reference(&suite, &sut, plat, exp, *versions);
        assert_reports_identical(&pooled, &reference, label);
    }
}

#[test]
fn extracted_duet_strategy_matches_preextraction_coordinator_exactly() {
    // Byte-identity oracle for the ExecutionStrategy refactor: the
    // coordinator loop pre-extraction survives verbatim as
    // `coordinator::reference::run_experiment_hardcoded`, and the
    // trait-dispatched `Duet` strategy must reproduce its reports
    // field for field — same RNG draw order, same schedule, same
    // billing — across serial, parallel A/A, crash-retry and
    // throttled regimes, plus the live early-stopping path.
    use elastibench::coordinator::reference::{
        run_experiment_hardcoded, run_experiment_live_hardcoded,
    };
    use elastibench::coordinator::LiveStopConfig;
    use elastibench::stats::{Analyzer, StoppingRule};

    let sut = SutConfig {
        benchmark_count: 12,
        true_changes: 3,
        faas_incompatible: 2,
        slow_setup: 1,
        ..SutConfig::default()
    };
    let suite = generate(&sut);

    let cases: Vec<(&str, PlatformConfig, ExperimentConfig, (Version, Version))> = vec![
        (
            "serial",
            PlatformConfig::default(),
            ExperimentConfig {
                calls_per_benchmark: 5,
                parallelism: 1,
                ..ExperimentConfig::default()
            },
            (Version::V1, Version::V2),
        ),
        (
            "parallel-aa",
            PlatformConfig::default(),
            ExperimentConfig {
                calls_per_benchmark: 6,
                parallelism: 40,
                ..ExperimentConfig::default()
            },
            (Version::V1, Version::V1),
        ),
        (
            "crashy",
            PlatformConfig {
                crash_probability: 0.15,
                ..PlatformConfig::default()
            },
            ExperimentConfig {
                calls_per_benchmark: 5,
                parallelism: 20,
                seed: 777,
                ..ExperimentConfig::default()
            },
            (Version::V1, Version::V2),
        ),
        (
            "throttled",
            PlatformConfig {
                concurrency_limit: 8,
                ..PlatformConfig::default()
            },
            ExperimentConfig {
                calls_per_benchmark: 5,
                parallelism: 30,
                seed: 31337,
                ..ExperimentConfig::default()
            },
            (Version::V1, Version::V2),
        ),
    ];
    for (label, plat, exp, versions) in &cases {
        let extracted = run_experiment(&suite, &sut, plat, exp, *versions);
        let frozen = run_experiment_hardcoded(&suite, &sut, plat, exp, *versions);
        assert_reports_identical(&extracted, &frozen, label);
    }

    // Live path: strategy-generic engine feed vs the frozen per-pair
    // push loop, including the cancellation bookkeeping.
    let analyzer = Analyzer::native();
    let (_, plat, exp, versions) = &cases[1];
    let cfg = LiveStopConfig {
        b: analyzer.b,
        alpha: analyzer.alpha,
        min_results: analyzer.min_results,
        rule: StoppingRule {
            step: exp.repeats_per_call.max(1),
            ..StoppingRule::default()
        },
        seed: exp.seed ^ 0xA11A,
    };
    let (extracted, live_a) =
        elastibench::coordinator::run_experiment_live(&suite, &sut, plat, exp, *versions, &cfg);
    let (frozen, live_b) =
        run_experiment_live_hardcoded(&suite, &sut, plat, exp, *versions, &cfg);
    assert_reports_identical(&extracted, &frozen, "live-aa");
    assert_eq!(live_a.stop_points, live_b.stop_points, "live-aa: stop points");
    assert_eq!(live_a.decided, live_b.decided, "live-aa: decided");
    assert_eq!(live_a.calls_canceled, live_b.calls_canceled, "live-aa: canceled");
}

#[test]
fn short_keepalive_experiment_completes_on_the_slot_map() {
    // Aggressive keepalive churn (the lambda-hyperscale regime, scaled
    // down): only run the pooled platform — the reference would corrupt
    // handles if a reap fired mid-flight — and sanity-check the run.
    let sut = SutConfig {
        benchmark_count: 15,
        true_changes: 3,
        faas_incompatible: 1,
        slow_setup: 1,
        ..SutConfig::default()
    };
    let suite = generate(&sut);
    let plat = PlatformConfig {
        keepalive_s: 20.0,
        ..PlatformConfig::default()
    };
    let exp = ExperimentConfig {
        calls_per_benchmark: 8,
        parallelism: 60,
        ..ExperimentConfig::default()
    };
    let report = run_experiment(&suite, &sut, &plat, &exp, (Version::V1, Version::V2));
    assert_eq!(report.calls_total, 15 * 8);
    assert!(report.platform.cold_starts >= 60, "burst cold-starts the fleet");
    assert!(report.cost_usd > 0.0);
    let runnable = suite
        .benchmarks
        .iter()
        .filter(|b| !b.writes_fs && b.setup_s < 6.0)
        .count();
    assert!(report.benchmarks_with_results(1) >= runnable);
}
