//! End-to-end tests of `elastibench serve` over real TCP: spawn the
//! server on an ephemeral port against a seeded store, speak raw
//! HTTP/1.1, and assert every endpoint's body is byte-identical to the
//! canonical `history::view` builders the CLI `--json` flags print —
//! plus pagination limits, ETag/If-None-Match revalidation, and the
//! `POST /record` write path.

use elastibench::history::{evaluate_latest, view, GatePolicy, HistoryStore, Timeline};
use elastibench::runtime::AnalysisOutput;
use elastibench::scenario::{catalog_entry, run_scenario, ScenarioReport};
use elastibench::serve::Server;
use elastibench::stats::{Analyzer, ChangeKind};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// A shrunk quick-smoke run (seconds of host time, pinned seeds).
fn tiny_report() -> ScenarioReport {
    let mut sc = catalog_entry("quick-smoke").unwrap();
    sc.sut.benchmark_count = 6;
    sc.sut.true_changes = 1;
    sc.sut.faas_incompatible = 1;
    sc.sut.slow_setup = 0;
    sc.exp.calls_per_benchmark = 6;
    sc.exp.parallelism = 8;
    run_scenario(&sc, &Analyzer::native()).unwrap()
}

/// Overwrite one NoChange verdict with a CI-backed +10% regression.
fn inject_regression(report: &mut ScenarioReport) {
    let idx = report
        .analysis
        .verdicts
        .iter()
        .position(|v| v.change == ChangeKind::NoChange)
        .expect("quick-smoke has a clean benchmark");
    let v = &mut report.analysis.verdicts[idx];
    v.output = AnalysisOutput {
        ci_lo_pct: 8.0,
        boot_median_pct: 10.0,
        ci_hi_pct: 12.0,
        median_v1: v.output.median_v1,
        median_v2: v.output.median_v1 * 1.10,
        point_pct: 10.0,
    };
    v.change = ChangeKind::Regression;
}

/// Seed a store with 4 runs (newest carries the regression) and spawn a
/// server over it on an ephemeral port.
fn spawn_seeded(tag: &str) -> (SocketAddr, HistoryStore) {
    let dir = std::env::temp_dir().join(format!("elastibench_serve_api_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    let store = HistoryStore::open(&dir);
    let mut report = tiny_report();
    for commit in ["s1", "s2", "s3"] {
        report.commit = commit.to_string();
        store.record(&report, commit).unwrap();
    }
    report.commit = "s4".to_string();
    inject_regression(&mut report);
    store.record(&report, "s4").unwrap();
    let (addr, _handle) = Server::bind("127.0.0.1:0", store.clone()).unwrap().spawn().unwrap();
    (addr, store)
}

struct Reply {
    status: u16,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl Reply {
    fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }

    fn text(&self) -> &str {
        std::str::from_utf8(&self.body).expect("UTF-8 body")
    }
}

/// One raw HTTP/1.1 exchange (the server closes after each response).
fn exchange(addr: SocketAddr, raw: &str) -> Reply {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    stream.write_all(raw.as_bytes()).unwrap();
    let mut bytes = Vec::new();
    stream.read_to_end(&mut bytes).unwrap();
    let split = bytes
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("header/body separator");
    let head = std::str::from_utf8(&bytes[..split]).unwrap();
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap();
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line {status_line:?}"));
    let headers = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
        .collect();
    Reply {
        status,
        headers,
        body: bytes[split + 4..].to_vec(),
    }
}

fn get(addr: SocketAddr, path: &str) -> Reply {
    exchange(addr, &format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n"))
}

fn get_if_none_match(addr: SocketAddr, path: &str, etag: &str) -> Reply {
    exchange(
        addr,
        &format!("GET {path} HTTP/1.1\r\nHost: t\r\nIf-None-Match: {etag}\r\n\r\n"),
    )
}

/// The policy every gate request in these tests pins via query
/// parameters (so the expected body does not depend on recipe files).
fn pinned_policy() -> GatePolicy {
    GatePolicy {
        window: 3,
        threshold_pct: 3.0,
        min_baseline: 1,
    }
}

const GATE_QUERY: &str = "/gate?scenario=quick-smoke&window=3&threshold=3&min_baseline=1";

#[test]
fn read_endpoints_are_byte_identical_to_the_cli_views() {
    let (addr, store) = spawn_seeded("views");

    let reply = get(addr, "/scenarios");
    assert_eq!(reply.status, 200);
    assert_eq!(
        reply.text(),
        format!("{}\n", view::scenarios_json(&store).unwrap())
    );

    let listing = store.runs_page("quick-smoke", 0, 2).unwrap();
    let reply = get(addr, "/runs/quick-smoke?page=1&per_page=2");
    assert_eq!(reply.status, 200);
    assert_eq!(
        reply.text(),
        format!("{}\n", view::runs_page_json("quick-smoke", &listing, 2))
    );

    let runs = store.runs("quick-smoke").unwrap();
    let (first, last) = (&runs[0].run_id, &runs[3].run_id);
    let a = store.load("quick-smoke", first).unwrap();
    let b = store.load("quick-smoke", last).unwrap();
    let reply = get(
        addr,
        &format!("/diff?scenario=quick-smoke&a={first}&b={last}"),
    );
    assert_eq!(reply.status, 200);
    assert_eq!(
        reply.text(),
        format!("{}\n", view::diff_json("quick-smoke", first, last, &a, &b))
    );

    let policy = pinned_policy();
    let outcome = evaluate_latest(&store, "quick-smoke", &policy).unwrap();
    assert!(!outcome.passed(), "seeded regression must show up");
    let reply = get(addr, GATE_QUERY);
    assert_eq!(reply.status, 200, "gate failures are data, not HTTP errors");
    assert_eq!(
        reply.text(),
        format!("{}\n", view::gate_json(&policy, &outcome))
    );

    let tl = Timeline::load_last(&store, "quick-smoke", 4).unwrap();
    let reply = get(addr, "/timeline?scenario=quick-smoke");
    assert_eq!(reply.status, 200);
    assert_eq!(reply.text(), format!("{}\n", view::timeline_json(&tl)));
    let tl2 = Timeline::load_last(&store, "quick-smoke", 2).unwrap();
    let reply = get(addr, "/timeline?scenario=quick-smoke&last=2");
    assert_eq!(reply.text(), format!("{}\n", view::timeline_json(&tl2)));

    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn run_documents_are_served_verbatim_with_strong_etags() {
    let (addr, store) = spawn_seeded("etag");
    let id = store.runs("quick-smoke").unwrap()[0].run_id.clone();

    let reply = get(addr, &format!("/run/quick-smoke/{id}"));
    assert_eq!(reply.status, 200);
    assert_eq!(
        reply.body,
        store.load_doc("quick-smoke", &id).unwrap().into_bytes(),
        "document bytes must round-trip unmodified"
    );
    let etag = reply.header("etag").expect("run responses carry an ETag").to_string();
    assert_eq!(etag, format!("\"quick-smoke/{id}\""));

    // Revalidation: matching tag -> empty 304; W/ and * match too.
    for tag in [etag.clone(), format!("W/{etag}"), "*".to_string()] {
        let reply = get_if_none_match(addr, &format!("/run/quick-smoke/{id}"), &tag);
        assert_eq!(reply.status, 304, "If-None-Match: {tag}");
        assert!(reply.body.is_empty());
        assert_eq!(reply.header("etag"), Some(etag.as_str()));
    }
    let reply = get_if_none_match(addr, &format!("/run/quick-smoke/{id}"), "\"stale\"");
    assert_eq!(reply.status, 200);

    // Gate and timeline revalidate the same way.
    for path in [GATE_QUERY.to_string(), "/timeline?scenario=quick-smoke".to_string()] {
        let first = get(addr, &path);
        let etag = first.header("etag").expect("cacheable endpoint").to_string();
        let revalidated = get_if_none_match(addr, &path, &etag);
        assert_eq!(revalidated.status, 304, "{path}");
        assert!(revalidated.body.is_empty());
    }

    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn record_appends_through_the_write_lock() {
    let (addr, store) = spawn_seeded("record");
    let doc = store
        .load_doc("quick-smoke", &store.runs("quick-smoke").unwrap()[0].run_id)
        .unwrap();

    let raw = format!(
        "POST /record?timestamp=t5 HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{doc}",
        doc.len()
    );
    let reply = exchange(addr, &raw);
    assert_eq!(reply.status, 201, "{}", reply.text());
    assert_eq!(store.runs_total("quick-smoke").unwrap(), 5);
    let newest = store.runs("quick-smoke").unwrap().pop().unwrap();
    assert!(newest.run_id.starts_with("0005-"));
    assert_eq!(newest.timestamp, "t5");
    assert_eq!(reply.text(), format!("{}\n", newest.to_json()));

    // A non-JSON body is refused and records nothing.
    let reply = exchange(
        addr,
        "POST /record HTTP/1.1\r\nHost: t\r\nContent-Length: 8\r\n\r\nnot json",
    );
    assert_eq!(reply.status, 400);
    assert_eq!(store.runs_total("quick-smoke").unwrap(), 5);

    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn http_errors_cover_the_wire_protocol() {
    let (addr, store) = spawn_seeded("errors");

    assert_eq!(get(addr, "/nope").status, 404);
    assert_eq!(get(addr, "/runs/never-recorded").status, 404);
    assert_eq!(get(addr, "/runs/quick-smoke?page=0").status, 400);
    assert_eq!(get(addr, "/runs/quick-smoke?per_page=0").status, 400);
    assert_eq!(get(addr, "/runs/quick-smoke?per_page=501").status, 400);
    assert_eq!(get(addr, "/gate").status, 400, "scenario is required");

    // Wrong method on a known path.
    let reply = exchange(addr, "DELETE /scenarios HTTP/1.1\r\nHost: t\r\n\r\n");
    assert_eq!(reply.status, 405);

    // A malformed request line gets a best-effort 400, not a hang.
    let reply = exchange(addr, "NOT-HTTP\r\n\r\n");
    assert_eq!(reply.status, 400);

    // The index page lists the endpoints.
    let reply = get(addr, "/");
    assert_eq!(reply.status, 200);
    assert!(reply.text().contains("\"endpoints\""));
    assert!(reply.text().contains("GET /scenarios"));

    let _ = std::fs::remove_dir_all(store.root());
}
