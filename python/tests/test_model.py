"""L2 model tests: sanitation, shapes, and AOT lowering."""

import numpy as np
import pytest

from compile.model import make_analyze, example_args, OUT_COLS
from compile.kernels.ref import bootstrap_ref
from compile import aot


class TestAnalyze:
    def test_shapes_and_tuple(self):
        m, b, n = 3, 64, 16
        analyze = make_analyze(m, b, n)
        rng = np.random.default_rng(0)
        v1 = rng.lognormal(0, 0.1, (m, n)).astype(np.float32)
        v2 = rng.lognormal(0, 0.1, (m, n)).astype(np.float32)
        nv = np.array([16, 8, 3], np.int32)
        idx = rng.integers(0, 2**31 - 1, (b, n)).astype(np.int32)
        out = analyze(v1, v2, nv, idx)
        assert isinstance(out, tuple) and len(out) == 1
        assert out[0].shape == (m, OUT_COLS)

    def test_matches_ref(self):
        m, b, n = 2, 128, 16
        analyze = make_analyze(m, b, n)
        rng = np.random.default_rng(1)
        v1 = rng.lognormal(0, 0.2, (m, n)).astype(np.float32)
        v2 = (rng.lognormal(0, 0.2, (m, n)) * 1.1).astype(np.float32)
        nv = np.array([16, 9], np.int32)
        idx = rng.integers(0, 2**31 - 1, (b, n)).astype(np.int32)
        out = np.asarray(analyze(v1, v2, nv, idx)[0])
        ref = bootstrap_ref(v1, v2, nv, idx)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_sanitizes_nonfinite_samples(self):
        # NaN/inf beyond n_valid must not leak into results.
        m, b, n = 1, 64, 8
        analyze = make_analyze(m, b, n)
        rng = np.random.default_rng(2)
        v1 = np.full((m, n), np.nan, np.float32)
        v2 = np.full((m, n), np.inf, np.float32)
        v1[0, :4] = [1.0, 1.1, 0.9, 1.05]
        v2[0, :4] = [1.2, 1.3, 1.1, 1.25]
        nv = np.array([4], np.int32)
        idx = rng.integers(0, 2**31 - 1, (b, n)).astype(np.int32)
        out = np.asarray(analyze(v1, v2, nv, idx)[0])
        assert np.isfinite(out).all()
        assert out[0, 1] > 0  # v2 clearly slower

    def test_clamps_n_valid(self):
        m, b, n = 1, 64, 8
        analyze = make_analyze(m, b, n)
        rng = np.random.default_rng(3)
        v1 = rng.lognormal(0, 0.1, (m, n)).astype(np.float32)
        v2 = rng.lognormal(0, 0.1, (m, n)).astype(np.float32)
        idx = rng.integers(0, 2**31 - 1, (b, n)).astype(np.int32)
        out_over = np.asarray(analyze(v1, v2, np.array([99], np.int32), idx)[0])
        out_exact = np.asarray(analyze(v1, v2, np.array([n], np.int32), idx)[0])
        np.testing.assert_allclose(out_over, out_exact)
        out_zero = np.asarray(analyze(v1, v2, np.array([0], np.int32), idx)[0])
        out_one = np.asarray(analyze(v1, v2, np.array([1], np.int32), idx)[0])
        np.testing.assert_allclose(out_zero, out_one)

    def test_negative_idx_bits_handled(self):
        m, b, n = 1, 64, 8
        analyze = make_analyze(m, b, n)
        rng = np.random.default_rng(4)
        v1 = rng.lognormal(0, 0.1, (m, n)).astype(np.float32)
        v2 = rng.lognormal(0, 0.1, (m, n)).astype(np.float32)
        nv = np.array([8], np.int32)
        idx = rng.integers(-(2**31) + 1, 2**31 - 1, (b, n)).astype(np.int32)
        out = np.asarray(analyze(v1, v2, nv, idx)[0])
        assert np.isfinite(out).all()

    def test_example_args_shapes(self):
        a = example_args(4, 128, 32)
        assert a[0].shape == (4, 32)
        assert a[2].shape == (4,)
        assert a[3].shape == (128, 32)


class TestAot:
    def test_lower_produces_hlo_text(self):
        text = aot.lower_variant(m=1, b=64, n=8)
        assert "HloModule" in text
        assert "f32[1,8]" in text          # v1 parameter shape
        assert "s32[64,8]" in text         # idx parameter shape

    def test_artifact_name(self):
        assert aot.artifact_name(8, 2048, 64) == "bootstrap_m8_b2048_n64.hlo.txt"

    def test_default_variants_cover_paper_geometries(self):
        variants = {(v["m"], v["b"], v["n"]) for v in aot.DEFAULT_VARIANTS}
        assert (128, 2048, 64) in variants       # full-suite batch
        assert any(n >= 200 for (_, _, n) in variants)  # Fig.7 sweep lanes
