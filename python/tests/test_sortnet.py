"""Tests for the bitonic sorting network (outside any Pallas kernel)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.sortnet import bitonic_sort, bitonic_stage_params


@pytest.mark.parametrize("n", [1, 2, 4, 8, 16, 64, 256])
def test_stage_count(n):
    stages = list(bitonic_stage_params(n))
    k = n.bit_length() - 1
    assert len(stages) == k * (k + 1) // 2


@pytest.mark.parametrize("n", [2, 4, 16, 64, 128])
def test_sorts_random_1d(n):
    rng = np.random.default_rng(n)
    x = rng.normal(size=n).astype(np.float32)
    out = np.asarray(bitonic_sort(x, axis=0))
    np.testing.assert_array_equal(out, np.sort(x))


def test_sorts_axis0_of_2d():
    rng = np.random.default_rng(7)
    x = rng.normal(size=(16, 5)).astype(np.float32)
    out = np.asarray(bitonic_sort(x, axis=0))
    np.testing.assert_array_equal(out, np.sort(x, axis=0))


def test_sorts_axis1_of_2d():
    rng = np.random.default_rng(8)
    x = rng.normal(size=(5, 32)).astype(np.float32)
    out = np.asarray(bitonic_sort(x, axis=1))
    np.testing.assert_array_equal(out, np.sort(x, axis=1))


def test_negative_axis():
    rng = np.random.default_rng(9)
    x = rng.normal(size=(3, 8)).astype(np.float32)
    out = np.asarray(bitonic_sort(x, axis=-1))
    np.testing.assert_array_equal(out, np.sort(x, axis=-1))


def test_rejects_non_power_of_two():
    x = np.zeros(6, np.float32)
    with pytest.raises(AssertionError):
        bitonic_sort(x, axis=0)


def test_already_sorted_and_reversed():
    x = np.arange(64, dtype=np.float32)
    np.testing.assert_array_equal(np.asarray(bitonic_sort(x)), x)
    np.testing.assert_array_equal(np.asarray(bitonic_sort(x[::-1].copy())), x)


def test_duplicates_and_sentinels():
    x = np.array([3.0, 3.0, 1.0, 3.0e38, 1.0, 3.0e38, 0.0, -1.0], np.float32)
    out = np.asarray(bitonic_sort(x))
    np.testing.assert_array_equal(out, np.sort(x))


@settings(max_examples=50, deadline=None)
@given(
    # allow_subnormal=False: XLA's CPU backend flushes denormals to zero,
    # which is FTZ platform behaviour, not a sorting bug.
    data=st.lists(
        st.floats(min_value=-1e6, max_value=1e6, width=32,
                  allow_subnormal=False),
        min_size=32, max_size=32,
    )
)
def test_property_matches_npsort(data):
    x = np.array(data, np.float32)
    out = np.asarray(bitonic_sort(x))
    np.testing.assert_array_equal(out, np.sort(x))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       log2n=st.integers(1, 9))
def test_property_random_lengths(seed, log2n):
    n = 1 << log2n
    rng = np.random.default_rng(seed)
    x = rng.lognormal(0.0, 2.0, n).astype(np.float32)
    out = np.asarray(bitonic_sort(x))
    np.testing.assert_array_equal(out, np.sort(x))
