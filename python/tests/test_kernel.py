"""Kernel-vs-reference correctness: the CORE L1 signal.

The Pallas bootstrap kernel (interpret mode) must agree with the pure
numpy oracle for every geometry, sample distribution, and n_valid edge
case. Hypothesis sweeps shapes/seeds; fixed tests pin the paper-relevant
geometries.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.bootstrap import (
    make_bootstrap_call,
    ci_order_statistics,
    vmem_bytes,
    OUT_CI_LO,
    OUT_MED,
    OUT_CI_HI,
    OUT_MED_V1,
    OUT_MED_V2,
    OUT_POINT,
    OUT_COLS,
)
from compile.kernels.ref import bootstrap_ref


def run_both(v1, v2, nv, idx, alpha=0.01):
    m, n = v1.shape
    b = idx.shape[0]
    out = np.asarray(make_bootstrap_call(m, b, n, alpha=alpha)(v1, v2, nv, idx))
    ref = bootstrap_ref(v1, v2, nv, idx, alpha=alpha)
    return out, ref


def make_inputs(rng, m, b, n, nv_list=None, shift=1.05):
    v1 = rng.lognormal(0, 0.1, (m, n)).astype(np.float32)
    v2 = (rng.lognormal(0, 0.1, (m, n)) * shift).astype(np.float32)
    if nv_list is None:
        nv = rng.integers(1, n + 1, m).astype(np.int32)
    else:
        nv = np.array(nv_list, np.int32)
    idx = rng.integers(0, 2**31 - 1, (b, n)).astype(np.int32)
    return v1, v2, nv, idx


class TestAgainstReference:
    @pytest.mark.parametrize("m,b,n", [(1, 64, 8), (2, 128, 16), (4, 64, 16),
                                       (3, 256, 32), (8, 64, 64)])
    def test_geometries(self, m, b, n):
        rng = np.random.default_rng(m * 1000 + b + n)
        v1, v2, nv, idx = make_inputs(rng, m, b, n)
        out, ref = run_both(v1, v2, nv, idx)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_full_lanes(self):
        rng = np.random.default_rng(1)
        v1, v2, nv, idx = make_inputs(rng, 4, 128, 16, nv_list=[16] * 4)
        out, ref = run_both(v1, v2, nv, idx)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_single_sample(self):
        rng = np.random.default_rng(2)
        v1, v2, nv, idx = make_inputs(rng, 3, 64, 16, nv_list=[1, 1, 1])
        out, ref = run_both(v1, v2, nv, idx)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
        # One sample -> zero-width CI at the exact relative difference.
        expected = (v2[:, 0] - v1[:, 0]) / v1[:, 0] * 100.0
        np.testing.assert_allclose(out[:, OUT_MED], expected, rtol=1e-4)
        np.testing.assert_allclose(out[:, OUT_CI_LO], out[:, OUT_CI_HI], rtol=1e-6)

    def test_paper_repeat_count_45(self):
        # The paper's 45-results-per-benchmark case in 64 lanes.
        rng = np.random.default_rng(3)
        v1, v2, nv, idx = make_inputs(rng, 4, 256, 64, nv_list=[45] * 4)
        out, ref = run_both(v1, v2, nv, idx)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_alpha_variants(self):
        rng = np.random.default_rng(4)
        v1, v2, nv, idx = make_inputs(rng, 2, 128, 16)
        for alpha in (0.01, 0.05, 0.10):
            out, ref = run_both(v1, v2, nv, idx, alpha=alpha)
            np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           log2b=st.integers(5, 8),
           log2n=st.integers(2, 6))
    def test_property_sweep(self, seed, log2b, log2n):
        rng = np.random.default_rng(seed)
        m, b, n = 2, 1 << log2b, 1 << log2n
        v1, v2, nv, idx = make_inputs(rng, m, b, n)
        out, ref = run_both(v1, v2, nv, idx)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(shift=st.floats(min_value=0.5, max_value=2.0),
           sigma=st.floats(min_value=0.0, max_value=1.0))
    def test_property_distributions(self, shift, sigma):
        rng = np.random.default_rng(int(shift * 1000 + sigma * 100))
        m, b, n = 2, 128, 16
        v1 = rng.lognormal(0, sigma, (m, n)).astype(np.float32)
        v2 = (rng.lognormal(0, sigma, (m, n)) * shift).astype(np.float32)
        nv = rng.integers(1, n + 1, m).astype(np.int32)
        idx = rng.integers(0, 2**31 - 1, (b, n)).astype(np.int32)
        out, ref = run_both(v1, v2, nv, idx)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


class TestSemantics:
    def test_identical_versions_zero_diff(self):
        rng = np.random.default_rng(5)
        v1, _, nv, idx = make_inputs(rng, 3, 128, 16)
        out = np.asarray(make_bootstrap_call(3, 128, 16)(v1, v1, nv, idx))
        np.testing.assert_allclose(out[:, OUT_MED], 0.0, atol=1e-6)
        np.testing.assert_allclose(out[:, OUT_CI_LO], 0.0, atol=1e-6)
        np.testing.assert_allclose(out[:, OUT_CI_HI], 0.0, atol=1e-6)

    def test_exact_scaling_detected(self):
        rng = np.random.default_rng(6)
        v1 = rng.lognormal(0, 0.3, (2, 16)).astype(np.float32)
        v2 = (v1 * 1.25).astype(np.float32)
        nv = np.array([16, 11], np.int32)
        idx = rng.integers(0, 2**31 - 1, (128, 16)).astype(np.int32)
        out = np.asarray(make_bootstrap_call(2, 128, 16)(v1, v2, nv, idx))
        np.testing.assert_allclose(out[:, OUT_MED], 25.0, rtol=1e-4)
        assert (out[:, OUT_CI_LO] > 0).all()  # change detected

    def test_ci_ordering_invariant(self):
        rng = np.random.default_rng(7)
        v1, v2, nv, idx = make_inputs(rng, 8, 128, 16, shift=1.2)
        out = np.asarray(make_bootstrap_call(8, 128, 16)(v1, v2, nv, idx))
        assert (out[:, OUT_CI_LO] <= out[:, OUT_MED]).all()
        assert (out[:, OUT_MED] <= out[:, OUT_CI_HI]).all()

    def test_median_columns_match_numpy(self):
        rng = np.random.default_rng(8)
        v1, v2, nv, idx = make_inputs(rng, 4, 64, 16)
        out = np.asarray(make_bootstrap_call(4, 64, 16)(v1, v2, nv, idx))
        for m in range(4):
            n = nv[m]
            s1 = np.sort(v1[m, :n])
            med1 = 0.5 * (s1[(n - 1) // 2] + s1[n // 2])
            np.testing.assert_allclose(out[m, OUT_MED_V1], med1, rtol=1e-6)

    def test_point_estimate_consistent(self):
        rng = np.random.default_rng(9)
        v1, v2, nv, idx = make_inputs(rng, 4, 64, 16)
        out = np.asarray(make_bootstrap_call(4, 64, 16)(v1, v2, nv, idx))
        expect = (out[:, OUT_MED_V2] - out[:, OUT_MED_V1]) / out[:, OUT_MED_V1] * 100
        np.testing.assert_allclose(out[:, OUT_POINT], expect, rtol=1e-4)

    def test_n_valid_clamped(self):
        # n_valid > N must behave like n_valid == N (model clamps, but the
        # kernel itself is exercised here with in-range data).
        rng = np.random.default_rng(10)
        v1, v2, _, idx = make_inputs(rng, 2, 64, 16)
        out_full = np.asarray(
            make_bootstrap_call(2, 64, 16)(v1, v2, np.array([16, 16], np.int32), idx))
        ref = bootstrap_ref(v1, v2, np.array([16, 16], np.int32), idx)
        np.testing.assert_allclose(out_full, ref, rtol=1e-5, atol=1e-5)


class TestHelpers:
    def test_ci_order_statistics_paper_geometry(self):
        assert ci_order_statistics(2048, 0.01) == (10, 2037)

    def test_ci_order_statistics_bounds(self):
        lo, hi = ci_order_statistics(64, 0.01)
        assert lo == 0 and hi == 63

    def test_rejects_non_pow2(self):
        with pytest.raises(ValueError):
            make_bootstrap_call(1, 100, 16)
        with pytest.raises(ValueError):
            make_bootstrap_call(1, 128, 20)

    def test_vmem_budget_production_geometry(self):
        # B=2048, N=64: must fit comfortably in a 16 MiB VMEM budget.
        assert vmem_bytes(2048, 64) < 4 * 1024 * 1024

    def test_out_cols(self):
        assert OUT_COLS == 6
