"""AOT lowering: JAX analysis graph -> HLO text artifacts for Rust/PJRT.

Emits HLO *text*, never ``.serialize()``: jax >= 0.5 writes HloModuleProto
with 64-bit instruction ids which the xla crate's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out ../artifacts

Writes one artifact per batch geometry plus ``manifest.json`` describing
shapes/columns so the Rust runtime can pick and pad without guessing.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import make_analyze, example_args, OUT_COLS

# Batch geometries exported by default. N=64 covers the paper's 45 results
# per microbenchmark (padded); B=2048 bootstrap resamples gives stable 99%
# CIs; M variants let the runtime trade padding waste for call count.
DEFAULT_VARIANTS = (
    {"m": 1, "b": 2048, "n": 64},
    {"m": 8, "b": 2048, "n": 64},
    {"m": 32, "b": 2048, "n": 64},
    {"m": 128, "b": 2048, "n": 64},
    # Wide-lane variant for the Fig.7 sweep (up to 200 results/benchmark).
    {"m": 32, "b": 2048, "n": 256},
)
ALPHA = 0.01


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(m: int, b: int, n: int, alpha: float = ALPHA) -> str:
    analyze = make_analyze(m, b, n, alpha=alpha, interpret=True)
    lowered = jax.jit(analyze).lower(*example_args(m, b, n))
    return to_hlo_text(lowered)


def artifact_name(m: int, b: int, n: int) -> str:
    return f"bootstrap_m{m}_b{b}_n{n}.hlo.txt"


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts",
                        help="output directory for artifacts")
    parser.add_argument("--variants", default="",
                        help="comma list like 8x2048x64 overriding defaults")
    args = parser.parse_args()

    variants = list(DEFAULT_VARIANTS)
    if args.variants:
        variants = []
        for spec in args.variants.split(","):
            m, b, n = (int(x) for x in spec.split("x"))
            variants.append({"m": m, "b": b, "n": n})

    os.makedirs(args.out, exist_ok=True)
    manifest = {"alpha": ALPHA, "out_cols": OUT_COLS, "artifacts": []}
    for v in variants:
        text = lower_variant(**v)
        name = artifact_name(**v)
        path = os.path.join(args.out, name)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["artifacts"].append({
            "file": name, "m": v["m"], "b": v["b"], "n": v["n"],
            "sha256_16": digest, "hlo_chars": len(text),
        })
        print(f"wrote {path} ({len(text)} chars, sha256/16={digest})")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
