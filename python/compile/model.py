"""L2: the batched statistical-analysis graph lowered for the Rust runtime.

ElastiBench's analysis step (paper §2/§6.1) is a pure function of the
collected measurements, so the whole graph — input sanitation, the L1
bootstrap kernel, and the change-classification margins — is authored in
JAX here and AOT-lowered once by ``aot.py``. Python never runs on the
experiment path; the Rust coordinator feeds measurement tensors into the
compiled artifact via PJRT.

Randomness lives in Rust: the coordinator draws the shared resample-index
tile ``idx`` from its seeded PRNG and passes it as an input, which keeps
the artifact deterministic and lets the native Rust engine replay the
identical algorithm for cross-validation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.bootstrap import make_bootstrap_call, OUT_COLS, PAD_SENTINEL


def make_analyze(m: int, b: int, n: int, alpha: float = 0.01,
                 interpret: bool = True):
    """Build the analysis function for a fixed batch geometry.

    Args:
      m: microbenchmarks per call (callers pad to this).
      b: bootstrap resamples (power of two).
      n: sample lanes (power of two).
      alpha: two-sided CI level (paper uses 99% -> alpha=0.01).

    Returns ``analyze(v1, v2, n_valid, idx) -> (out[M, 6],)`` — a 1-tuple
    because the AOT bridge lowers with ``return_tuple=True`` and the Rust
    side unwraps with ``to_tuple1``.
    """
    kernel = make_bootstrap_call(m, b, n, alpha=alpha, interpret=interpret)

    def analyze(v1, v2, n_valid, idx):
        # Sanitize: non-finite samples become large-finite padding
        # (excluded from medians as long as n_valid is honest), counts are
        # clamped to the lane width, index bits are forced non-negative.
        v1 = jnp.where(jnp.isfinite(v1), v1, PAD_SENTINEL).astype(jnp.float32)
        v2 = jnp.where(jnp.isfinite(v2), v2, PAD_SENTINEL).astype(jnp.float32)
        nv = jnp.clip(n_valid.astype(jnp.int32), 1, n)
        ix = jnp.abs(idx.astype(jnp.int32))
        return (kernel(v1, v2, nv, ix),)

    return analyze


def example_args(m: int, b: int, n: int):
    """ShapeDtypeStructs matching ``make_analyze``'s signature."""
    return (
        jax.ShapeDtypeStruct((m, n), jnp.float32),
        jax.ShapeDtypeStruct((m, n), jnp.float32),
        jax.ShapeDtypeStruct((m,), jnp.int32),
        jax.ShapeDtypeStruct((b, n), jnp.int32),
    )


__all__ = ["make_analyze", "example_args", "OUT_COLS"]
