"""Pure-numpy oracle for the bootstrap kernel.

Implements the exact same algorithm as ``bootstrap.py`` (same median and
order-statistic conventions, same index-mod resampling) with plain numpy
loops, so pytest can assert bit-level-comparable agreement and the Rust
native engine has a documented specification to match.
"""

from __future__ import annotations

import numpy as np

from .bootstrap import ci_order_statistics, OUT_COLS


def median_order_stat(sorted_vals: np.ndarray) -> float:
    """Median as the average of the two central order statistics."""
    n = sorted_vals.shape[-1]
    return 0.5 * (sorted_vals[..., (n - 1) // 2] + sorted_vals[..., n // 2])


def bootstrap_ref(v1: np.ndarray, v2: np.ndarray, n_valid: np.ndarray,
                  idx: np.ndarray, alpha: float = 0.01) -> np.ndarray:
    """Reference bootstrap analysis.

    Args:
      v1, v2: ``[M, N]`` float32 sample matrices (padding beyond
        ``n_valid[m]`` is ignored).
      n_valid: ``[M]`` int32 valid-sample counts (clamped to ``[1, N]``).
      idx: ``[B, N]`` non-negative int32 resample bits, shared across
        benchmarks; resample index = ``idx % n_valid[m]``.
      alpha: two-sided CI level.

    Returns ``[M, 6]`` float32 with columns
    (ci_lo, boot_median, ci_hi, med_v1, med_v2, point_diff_percent).
    """
    v1 = np.asarray(v1, np.float32)
    v2 = np.asarray(v2, np.float32)
    m_count, n_lanes = v1.shape
    b = idx.shape[0]
    lo_q, hi_q = ci_order_statistics(b, alpha)
    out = np.zeros((m_count, OUT_COLS), np.float32)

    for m in range(m_count):
        n = int(np.clip(n_valid[m], 1, n_lanes))
        r = idx[:, :n] % n                                 # [B, n]
        g1 = np.sort(v1[m, r].astype(np.float32), axis=1)  # [B, n]
        g2 = np.sort(v2[m, r].astype(np.float32), axis=1)
        med1 = median_order_stat(g1)
        med2 = median_order_stat(g2)
        with np.errstate(divide="ignore", invalid="ignore"):
            rel = np.where(med1 != 0.0, (med2 - med1) / med1 * 100.0, 0.0)
        rel = np.sort(rel.astype(np.float32))
        med_v1 = median_order_stat(np.sort(v1[m, :n]))
        med_v2 = median_order_stat(np.sort(v2[m, :n]))
        point = (med_v2 - med_v1) / med_v1 * 100.0 if med_v1 != 0.0 else 0.0
        out[m] = (
            rel[lo_q],
            0.5 * (rel[(b - 1) // 2] + rel[b // 2]),
            rel[hi_q],
            med_v1,
            med_v2,
            point,
        )
    return out
