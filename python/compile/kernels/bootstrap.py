"""L1 Pallas kernel: batched bootstrap confidence intervals of the median
relative performance difference between two SUT versions.

This is ElastiBench's numeric hot spot (paper §2, §6.1 "Statistical
Analysis"): for every microbenchmark, resample the ``n_valid`` measured
results of both versions ``B`` times with replacement, take the median of
each resample, form the relative difference of the medians (in percent),
and report the (alpha/2, 50%, 1-alpha/2) order statistics of the ``B``
bootstrap differences together with the raw point estimates.

Kernel layout (TPU-shaped, run with ``interpret=True`` on CPU):

* grid = (M,) — one program per microbenchmark;
* each program stages the two ``N``-lane sample rows plus a shared
  ``B x N`` resample-index tile in VMEM, gathers both versions'
  resample matrices (``B x N`` f32, 512 KiB each at B=2048/N=64),
  sorts rows with a data-oblivious bitonic network, and reduces
  medians via one-hot dot products (no data-dependent indexing);
* the ``B`` bootstrap statistics are bitonic-sorted once more to read
  off the CI bounds as static order statistics.

Everything is compare/permute bound — no MXU use; see DESIGN.md
§Hardware-Adaptation and EXPERIMENTS.md §Perf for the VMEM budget table.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .sortnet import bitonic_sort

# Output column layout of the kernel (one row per microbenchmark).
OUT_CI_LO = 0      # lower bootstrap CI bound of the relative diff [%]
OUT_MED = 1        # median of the bootstrap relative diffs [%]
OUT_CI_HI = 2      # upper bootstrap CI bound [%]
OUT_MED_V1 = 3     # raw median of version 1 samples
OUT_MED_V2 = 4     # raw median of version 2 samples
OUT_POINT = 5      # raw relative diff of the medians [%]
OUT_COLS = 6

# Large finite padding sentinel: sorts past every real measurement but
# multiplies by 0 cleanly in the one-hot median reduction (+inf would
# produce NaN via inf * 0).
PAD_SENTINEL = 3.0e38


def ci_order_statistics(b: int, alpha: float) -> tuple[int, int]:
    """Static order-statistic indices used for the CI bounds.

    ``lo = floor(alpha/2 * (B-1))`` and ``hi = ceil((1-alpha/2) * (B-1))``,
    mirroring the percentile-bootstrap convention without interpolation so
    the Rust native engine and the reference oracle can match exactly.
    """
    lo = math.floor(alpha / 2.0 * (b - 1))
    hi = math.ceil((1.0 - alpha / 2.0) * (b - 1))
    return lo, hi


def _masked_median(sorted_rows: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
    """Median of the first ``n`` entries of ascending-sorted rows.

    ``sorted_rows`` is ``[..., N]`` with ``+inf`` padding beyond ``n``;
    the median is read out with one-hot dot products so there is no
    data-dependent gather (TPU-friendly).
    """
    length = sorted_rows.shape[-1]
    lane = jax.lax.iota(jnp.int32, length)
    lo_i = (n - 1) // 2
    hi_i = n // 2
    oh_lo = (lane == lo_i).astype(sorted_rows.dtype)
    oh_hi = (lane == hi_i).astype(sorted_rows.dtype)
    return 0.5 * (sorted_rows @ oh_lo + sorted_rows @ oh_hi)


def _bootstrap_kernel(v1_ref, v2_ref, n_ref, idx_ref, out_ref, *,
                      b: int, n_lanes: int, lo_q: int, hi_q: int):
    """Pallas kernel body for one microbenchmark (one grid step)."""
    v1 = v1_ref[0, :]                      # [N] f32, +inf padded
    v2 = v2_ref[0, :]
    n = jnp.maximum(n_ref[0], 1)           # scalar int32, >= 1
    idx = idx_ref[...]                     # [B, N] int32, >= 0

    col = jax.lax.broadcasted_iota(jnp.int32, (b, n_lanes), 1)
    valid = col < n
    r = jnp.where(valid, idx % n, 0)       # resample indices < n

    # Gather resample matrices; invalid lanes become large-finite padding
    # so the bitonic sort pushes them past the median positions. A finite
    # sentinel (not +inf) keeps the one-hot median dot products NaN-free
    # (inf * 0 = NaN).
    inf = jnp.float32(PAD_SENTINEL)
    g1 = jnp.where(valid, v1[r], inf)      # [B, N]
    g2 = jnp.where(valid, v2[r], inf)

    med1 = _masked_median(bitonic_sort(g1, axis=1), n)   # [B]
    med2 = _masked_median(bitonic_sort(g2, axis=1), n)

    rel = jnp.where(med1 != 0.0, (med2 - med1) / med1 * 100.0, 0.0)
    rel_sorted = bitonic_sort(rel, axis=0)               # [B]

    # Raw medians of the original (un-resampled) rows.
    lane = jax.lax.iota(jnp.int32, n_lanes)
    v1p = jnp.where(lane < n, v1, inf)
    v2p = jnp.where(lane < n, v2, inf)
    med_v1 = _masked_median(bitonic_sort(v1p, axis=0)[None, :], n)[0]
    med_v2 = _masked_median(bitonic_sort(v2p, axis=0)[None, :], n)[0]
    point = jnp.where(med_v1 != 0.0,
                      (med_v2 - med_v1) / med_v1 * 100.0, 0.0)

    med_boot = 0.5 * (rel_sorted[(b - 1) // 2] + rel_sorted[b // 2])
    out_ref[0, :] = jnp.stack([
        rel_sorted[lo_q], med_boot, rel_sorted[hi_q],
        med_v1, med_v2, point,
    ])


def make_bootstrap_call(m: int, b: int, n: int, alpha: float = 0.01,
                        interpret: bool = True):
    """Build the batched bootstrap analysis as a ``pallas_call``.

    Args:
      m: number of microbenchmarks analyzed per call (grid size).
      b: bootstrap resamples per microbenchmark (power of two).
      n: sample lanes per version (power of two, >= max n_valid).
      alpha: two-sided CI level (0.01 -> 99% CI as in the paper).
      interpret: must stay True on CPU PJRT (Mosaic custom-calls cannot
        run there); kept as a flag for a real-TPU compile-only path.

    Returns a function ``(v1[M,N] f32, v2[M,N] f32, n_valid[M] i32,
    idx[B,N] i32) -> out[M,6] f32`` (columns per ``OUT_*``).
    """
    if b & (b - 1) or n & (n - 1):
        raise ValueError(f"B and N must be powers of two, got B={b} N={n}")
    lo_q, hi_q = ci_order_statistics(b, alpha)
    kernel = partial(_bootstrap_kernel, b=b, n_lanes=n, lo_q=lo_q, hi_q=hi_q)
    return pl.pallas_call(
        kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),     # v1 row
            pl.BlockSpec((1, n), lambda i: (i, 0)),     # v2 row
            pl.BlockSpec((1,), lambda i: (i,)),          # n_valid
            pl.BlockSpec((b, n), lambda i: (0, 0)),      # shared idx tile
        ],
        out_specs=pl.BlockSpec((1, OUT_COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, OUT_COLS), jnp.float32),
        interpret=interpret,
    )


def vmem_bytes(b: int, n: int) -> int:
    """Estimated peak VMEM per grid step (see EXPERIMENTS.md §Perf)."""
    resample = 2 * b * n * 4          # g1/g2 gather+sort buffers
    idx = b * n * 4                   # shared index tile
    rows = 2 * n * 4 + b * 4          # sample rows + rel vector
    return resample + idx + rows
