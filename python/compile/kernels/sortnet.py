"""Bitonic sorting networks for Pallas kernels.

All sorting inside the L1 kernel uses data-oblivious bitonic networks:
a fixed sequence of compare-exchange stages whose structure depends only
on the (static, power-of-two) length. This vectorizes cleanly on VPU-style
wide registers (no data-dependent control flow) and is the standard way to
sort small, fixed-size tiles on TPU-like hardware.

The network sorts along a chosen axis of an array; every stage is a single
masked min/max over a lane permutation, so a length-``n`` sort costs
``log2(n) * (log2(n)+1) / 2`` vectorized compare-exchange steps
(21 for n=64, 66 for n=2048).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def bitonic_stage_params(n: int):
    """Yield the static ``(k, j)`` block/stride pairs of a bitonic sort of
    length ``n`` (``log2(n) * (log2(n)+1) / 2`` stages).
    """
    assert _is_pow2(n), f"bitonic sort needs power-of-two length, got {n}"
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            yield k, j
            j //= 2
        k *= 2


def bitonic_sort(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Sort ``x`` ascending along ``axis`` with a bitonic network.

    The length of ``axis`` must be a power of two (pad with ``+inf``
    beforehand for partial sorts). Works on any dtype with total order
    under min/max; NaNs must be removed/padded by the caller.

    All lane bookkeeping (partner index, keep-min mask) is derived from
    ``lax.iota`` *inside* the trace — Pallas kernel bodies may not capture
    host-side constant arrays.
    """
    axis = axis % x.ndim
    n = x.shape[axis]
    # Move the sort axis last for cheap gathers, then restore.
    xt = jnp.moveaxis(x, axis, -1)
    lanes = jax.lax.iota(jnp.int32, n)
    for k, j in bitonic_stage_params(n):
        partner = lanes ^ j
        # Ascending block if bit log2(k) of the lane index is 0; a lane
        # keeps the minimum when it is the lower index of an ascending
        # pair or the higher index of a descending pair.
        asc = (lanes & k) == 0
        keep_min = jnp.where(lanes < partner, asc, ~asc)
        partner_vals = jnp.take(xt, partner, axis=-1)
        mn = jnp.minimum(xt, partner_vals)
        mx = jnp.maximum(xt, partner_vals)
        xt = jnp.where(keep_min, mn, mx)
    return jnp.moveaxis(xt, -1, axis)
