//! Regenerates the paper's headline cost/duration comparison (§1, §6.3,
//! and the per-experiment numbers of §6.2.1–§6.2.5): every FaaS
//! experiment vs the VM baseline.
//!
//! Run: `cargo bench --bench tab_cost_duration`

use elastibench::exp::{
    aa, baseline, lower_memory, replication, single_repeat, vm_original, Workbench,
};
use elastibench::report::{experiment_summary_table, SummaryRow};

fn main() {
    let wb = Workbench::native();

    let vm = vm_original(&wb).expect("vm baseline");
    let experiments = [
        aa(&wb).expect("aa"),
        baseline(&wb).expect("baseline"),
        replication(&wb).expect("replication"),
        lower_memory(&wb).expect("lower-memory"),
        single_repeat(&wb).expect("single-repeat"),
    ];

    let mut rows = vec![SummaryRow {
        label: "vm-original [23]".into(),
        analyzed: vm.analysis.verdicts.len(),
        changes: vm.analysis.change_count(),
        wall_s: vm.report.wall_s,
        cost_usd: vm.report.cost_usd,
        cold_starts: 0,
    }];
    for r in &experiments {
        rows.push(SummaryRow {
            label: r.analysis.label.clone(),
            analyzed: r.analysis.verdicts.len(),
            changes: r.analysis.change_count(),
            wall_s: r.report.wall_s,
            cost_usd: r.report.cost_usd,
            cold_starts: r.report.platform.cold_starts,
        });
    }

    println!("Headline table — cost & duration, FaaS experiments vs VM baseline\n");
    print!("{}", experiment_summary_table(&rows));

    let base = &experiments[1];
    let speedup = vm.report.wall_s / base.report.wall_s;
    let time_frac = base.report.wall_s / vm.report.wall_s * 100.0;
    println!(
        "\nbaseline runs in {time_frac:.1}% of the VM time ({speedup:.1}x speedup; \
         paper: ~4.6–6% / ≤15 min vs ~4 h)"
    );
    println!(
        "baseline cost ${:.2} vs VM ${:.2} (paper: $0.18–1.18 vs $1.14–1.18)",
        base.report.cost_usd, vm.report.cost_usd
    );
    println!(
        "\nper-experiment paper anchors: A/A ~8 min/$1.18 | baseline ~11 min/$0.18(†) | \
         replication ~9 min/$1.18 | lower-memory ~12 min/$0.69 | single-repeat ~17 min/$0.49"
    );
    println!("(† the paper's baseline cost is inconsistent with its A/A twin; see DESIGN.md §4)");

    assert!(speedup > 10.0, "FaaS must be an order of magnitude faster");
    assert!(
        base.report.cost_usd < 1.5 * vm.report.cost_usd,
        "FaaS cost must be comparable or lower"
    );
}
