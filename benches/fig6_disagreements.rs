//! Regenerates paper Fig. 6: the maximum performance difference of
//! microbenchmarks on which two experiments disagree about whether a
//! performance change happened (§6.2.6).
//!
//! Run: `cargo bench --bench fig6_disagreements`

use elastibench::exp::{baseline, lower_memory, replication, single_repeat, Workbench};
use elastibench::report::render_cdf;
use elastibench::stats::possible_changes;
use elastibench::util::stats::percentile_sorted;

fn main() {
    let wb = Workbench::native();
    let base = baseline(&wb).expect("baseline");
    let repl = replication(&wb).expect("replication");
    let low = lower_memory(&wb).expect("lower-memory");
    let single = single_repeat(&wb).expect("single-repeat");

    let pcs = possible_changes(&[
        &base.analysis,
        &repl.analysis,
        &low.analysis,
        &single.analysis,
    ]);
    let mut mags: Vec<f64> = pcs.iter().map(|(_, m)| *m).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());

    println!("Fig. 6 — possible performance changes across experiment pairs");
    if mags.is_empty() {
        println!("(no disagreements — increase noise or decrease effects)");
        return;
    }
    print!(
        "{}",
        render_cdf(&mags, 64, 12, "max |diff| when disagreeing [%]")
    );
    println!("\nper-benchmark possible changes:");
    for (name, m) in &pcs {
        println!("  {name:<44} {m:>6.2}%");
    }
    println!(
        "\nn {} | median {:.2}% (paper 1.58%) | p75 {:.2}% (paper 3.06%) | max {:.2}% (paper 7.6%)",
        mags.len(),
        percentile_sorted(&mags, 50.0),
        percentile_sorted(&mags, 75.0),
        mags.last().unwrap(),
    );
    assert!(
        *mags.last().unwrap() < 20.0,
        "disagreements involve small effects only"
    );
}
