//! §Perf: bootstrap-analysis throughput — the native Rust engine vs the
//! AOT-compiled XLA artifact, at the paper's production geometry
//! (B = 2048 resamples, N = 64 lanes, 45 valid samples per benchmark).
//!
//! Reported unit: analyzed benchmark-CIs per second. See `docs/perf.md`
//! for the recorded numbers and the optimization log.
//!
//! Run: `cargo bench --bench perf_analysis`

use elastibench::runtime::{AnalysisEngine, Manifest};
use elastibench::stats::{bootstrap_native, bootstrap_row_reference};
use elastibench::util::benchkit::time;
use elastibench::util::Rng;

const B: usize = 2048;
const N: usize = 64;

fn inputs(m: usize) -> (Vec<f32>, Vec<f32>, Vec<i32>, Vec<i32>) {
    let mut rng = Rng::new(42);
    let mut v1 = vec![1.0f32; m * N];
    let mut v2 = vec![1.0f32; m * N];
    let n_valid = vec![45i32; m];
    for row in 0..m {
        for j in 0..45 {
            v1[row * N + j] = rng.lognormal(0.0, 0.05) as f32;
            v2[row * N + j] = rng.lognormal(0.03, 0.05) as f32;
        }
    }
    let mut idx = vec![0i32; B * N];
    rng.fill_index_bits(&mut idx);
    (v1, v2, n_valid, idx)
}

fn main() {
    println!("bootstrap analysis throughput (B={B}, N={N}, n_valid=45)\n");

    // Pre-§Perf baseline: the original gather + two-quickselect kernel,
    // single-threaded (kept in-tree for this comparison).
    {
        let m = 32;
        let (v1, v2, _n_valid, idx) = inputs(m);
        let stats = time("native REFERENCE (pre-perf), m=32", 1, 5, || {
            (0..m)
                .map(|row| {
                    bootstrap_row_reference(
                        &v1[row * N..row * N + 45],
                        &v2[row * N..row * N + 45],
                        &idx,
                        B,
                        N,
                        0.01,
                    )
                })
                .collect::<Vec<_>>()
        });
        println!("{}", stats.report(Some(m as f64)));
    }

    for m in [8usize, 32, 128] {
        let (v1, v2, n_valid, idx) = inputs(m);
        let stats = time(&format!("native OPTIMIZED,  batch m={m}"), 1, 7, || {
            bootstrap_native(&v1, &v2, &n_valid, &idx, m, B, N, 0.01)
        });
        println!("{}", stats.report(Some(m as f64)));
    }

    match Manifest::load(&elastibench::artifacts_dir()) {
        Ok(manifest) => {
            for m in [8usize, 32, 128] {
                let info = manifest
                    .artifacts
                    .iter()
                    .find(|a| a.m == m && a.n == N && a.b == B)
                    .expect("artifact variant");
                let engine = AnalysisEngine::load(&manifest.path_of(info), info.m, info.b, info.n)
                    .expect("compile artifact");
                let (v1, v2, n_valid, idx) = inputs(m);
                let stats = time(&format!("xla artifact,     batch m={m}"), 1, 7, || {
                    engine.analyze(&v1, &v2, &n_valid, &idx).expect("analyze")
                });
                println!("{}", stats.report(Some(m as f64)));
            }
        }
        Err(e) => println!("(skipping XLA engine: {e:#} — run `make artifacts`)"),
    }

    println!(
        "\nnote: interpret-mode Pallas lowers to plain HLO, so the XLA path here measures\n\
         the XLA:CPU-compiled kernel; real-TPU numbers are estimated from the VMEM/roofline\n\
         analysis in docs/perf.md."
    );
}
