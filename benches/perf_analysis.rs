//! §Perf: bootstrap-analysis throughput — the native Rust engine vs the
//! AOT-compiled XLA artifact, at the paper's production geometry
//! (B = 2048 resamples, N = 64 lanes, 45 valid samples per benchmark),
//! plus the streaming-analysis comparisons added with the incremental
//! engine: per-prefix clone replay vs [`IncrementalBootstrap`], and
//! per-variant suite analysis vs the batched [`Analyzer::analyze_many`]
//! pool.
//!
//! Reported unit: analyzed benchmark-CIs per second. See `docs/perf.md`
//! for the recorded numbers and the optimization log.
//!
//! Run: `cargo bench --bench perf_analysis`
//!
//! Flags (after `--`):
//!
//! * `--smoke`        shortened CI variant (fewer iterations, same
//!                    shapes);
//! * `--json PATH`    additionally emit a machine-readable
//!                    `elastibench.bench-report.v1` document (CI writes
//!                    `BENCH_analysis.json`; format in
//!                    `docs/benchmarks.md`).

use elastibench::runtime::{AnalysisEngine, Manifest};
use elastibench::stats::{
    bootstrap_native, bootstrap_row_reference, Analyzer, IncrementalBootstrap, Measurements,
    StoppingRule,
};
use elastibench::util::benchkit::{time, BenchReport};
use elastibench::util::Rng;

const B: usize = 2048;
const N: usize = 64;

fn inputs(m: usize) -> (Vec<f32>, Vec<f32>, Vec<i32>, Vec<i32>) {
    let mut rng = Rng::new(42);
    let mut v1 = vec![1.0f32; m * N];
    let mut v2 = vec![1.0f32; m * N];
    let n_valid = vec![45i32; m];
    for row in 0..m {
        for j in 0..45 {
            v1[row * N + j] = rng.lognormal(0.0, 0.05) as f32;
            v2[row * N + j] = rng.lognormal(0.03, 0.05) as f32;
        }
    }
    let mut idx = vec![0i32; B * N];
    rng.fill_index_bits(&mut idx);
    (v1, v2, n_valid, idx)
}

/// Per-benchmark duet sample streams for the streaming-analysis case:
/// mostly tight streams that hit the CI target at the first checkpoint,
/// every fifth noisy enough to ride out the whole 45-result budget.
fn streams(count: usize) -> Vec<(String, Vec<f64>, Vec<f64>)> {
    let base = Rng::new(0x5EED_50);
    (0..count)
        .map(|i| {
            let mut r = base.fork(i as u64);
            let sigma = if i % 5 == 4 { 0.2 } else { 0.005 };
            let v1: Vec<f64> = (0..45).map(|_| r.lognormal(0.0, sigma)).collect();
            let v2: Vec<f64> = (0..45).map(|_| r.lognormal(0.0, sigma)).collect();
            (format!("bench-{i:02}"), v1, v2)
        })
        .collect()
}

/// The pre-incremental stopping-point computation: clone every prefix
/// into a fresh `Measurements` and run the full suite analyzer on it —
/// one resample-index tile regeneration, argsort and allocation set per
/// checkpoint (this is what `required_results` did before the §Perf L3
/// borrowed-window + incremental work, and still does on XLA).
fn replay_stop(
    analyzer: &Analyzer,
    rule: &StoppingRule,
    name: &str,
    v1: &[f64],
    v2: &[f64],
    seed: u64,
) -> usize {
    let have = v1.len().min(rule.max_results);
    let mut k = rule.min_results.max(analyzer.min_results);
    while k <= have {
        let prefix = Measurements {
            name: name.to_string(),
            v1: v1[..k].to_vec(),
            v2: v2[..k].to_vec(),
        };
        let analysis = analyzer
            .analyze("replay", std::slice::from_ref(&prefix), seed)
            .expect("replay analyze");
        if analysis.verdicts[0].output.ci_size_pct() <= rule.target_ci_pct {
            return k;
        }
        k += rule.step;
    }
    have
}

/// Stream every sample through one [`IncrementalBootstrap`] (the live
/// coordinator path) and collect the per-benchmark stop points.
fn incremental_stops(
    data: &[(String, Vec<f64>, Vec<f64>)],
    rule: StoppingRule,
    seed: u64,
) -> Vec<usize> {
    let mut engine = IncrementalBootstrap::new(data.len(), B, 0.01, 10, rule, seed);
    for (bench, (_, v1, v2)) in data.iter().enumerate() {
        for (a, b) in v1.iter().zip(v2) {
            engine.push_sample(bench, *a, *b).expect("push sample");
        }
    }
    (0..data.len()).map(|i| engine.stop_point(i)).collect()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .map(|i| args.get(i + 1).expect("--json needs a PATH").clone());
    let mut report = BenchReport::new("analysis");

    println!("bootstrap analysis throughput (B={B}, N={N}, n_valid=45)\n");

    // Pre-§Perf baseline: the original gather + two-quickselect kernel,
    // single-threaded (kept in-tree for this comparison).
    {
        let m = 32;
        let (v1, v2, _n_valid, idx) = inputs(m);
        let stats = time("native REFERENCE (pre-perf), m=32", 1, if smoke { 3 } else { 5 }, || {
            (0..m)
                .map(|row| {
                    bootstrap_row_reference(
                        &v1[row * N..row * N + 45],
                        &v2[row * N..row * N + 45],
                        &idx,
                        B,
                        N,
                        0.01,
                    )
                })
                .collect::<Vec<_>>()
        });
        println!("{}", stats.report(Some(m as f64)));
        report.case(&stats, Some(m as f64));
    }

    for m in [8usize, 32, 128] {
        let (v1, v2, n_valid, idx) = inputs(m);
        let stats = time(&format!("native OPTIMIZED,  batch m={m}"), 1, if smoke { 3 } else { 7 }, || {
            bootstrap_native(&v1, &v2, &n_valid, &idx, m, B, N, 0.01)
        });
        println!("{}", stats.report(Some(m as f64)));
        report.case(&stats, Some(m as f64));
        if m == 128 {
            report.metric("native_m128_cis_per_s", m as f64 / stats.median_s);
        }
    }

    // Streaming analysis (§Perf L3): per-prefix clone replay vs the
    // incremental engine, over a 50-benchmark suite of duet streams.
    // Both sides apply the identical stopping rule and resample tiles;
    // their stop points are asserted equal below.
    {
        let suite = streams(50);
        let rule = StoppingRule::default();
        let seed = 0xA11A ^ 0x5EED_50u64;
        let analyzer = Analyzer::native();
        let iters = if smoke { 3 } else { 7 };
        let replay = time(&format!("replay (per-prefix analyze), {} benches", suite.len()), 1, iters, || {
            suite
                .iter()
                .map(|(name, v1, v2)| replay_stop(&analyzer, &rule, name, v1, v2, seed))
                .collect::<Vec<_>>()
        });
        println!("{}", replay.report(Some(suite.len() as f64)));
        report.case(&replay, Some(suite.len() as f64));
        let incremental = time(
            &format!("incremental streaming,       {} benches", suite.len()),
            1,
            iters,
            || incremental_stops(&suite, rule, seed),
        );
        println!("{}", incremental.report(Some(suite.len() as f64)));
        report.case(&incremental, Some(suite.len() as f64));

        // Differential sanity: the two formulations must land on the
        // same stop points, or the speedup compares different work.
        let replay_pts: Vec<usize> = suite
            .iter()
            .map(|(name, v1, v2)| replay_stop(&analyzer, &rule, name, v1, v2, seed))
            .collect();
        let incr_pts = incremental_stops(&suite, rule, seed);
        assert_eq!(replay_pts, incr_pts, "stop points must agree");

        let speedup = replay.median_s / incremental.median_s;
        println!("incremental vs replay speedup ({} benches): {speedup:.1}x", suite.len());
        report.metric("incremental_vs_replay_speedup", speedup);
        report.metric("incremental_suite_benchmarks", suite.len() as f64);
    }

    // Batched multi-variant analysis: a sweep-sized [matrix] expansion
    // analyzed per variant (one bootstrap pool spin-up each) vs through
    // one shared row queue (`Analyzer::analyze_many`).
    {
        let nvariants = if smoke { 8 } else { 16 };
        let variants: Vec<(String, Vec<Measurements>)> = (0..nvariants)
            .map(|v| {
                let mut r = Rng::new(0xBA7C).fork(v as u64);
                let ms: Vec<Measurements> = (0..16)
                    .map(|i| Measurements {
                        name: format!("b{i:02}"),
                        v1: (0..45).map(|_| r.lognormal(0.0, 0.05)).collect(),
                        v2: (0..45).map(|_| r.lognormal(0.01, 0.05)).collect(),
                    })
                    .collect();
                (format!("variant-{v:02}"), ms)
            })
            .collect();
        let jobs: Vec<(String, &[Measurements], u64)> = variants
            .iter()
            .enumerate()
            .map(|(v, (label, ms))| (label.clone(), ms.as_slice(), 500 + v as u64))
            .collect();
        let analyzer = Analyzer::native();
        let iters = if smoke { 3 } else { 5 };
        let per_variant = time(&format!("per-variant analyze, {nvariants} variants x 16"), 1, iters, || {
            jobs.iter()
                .map(|(label, ms, seed)| analyzer.analyze(label, ms, *seed).expect("analyze"))
                .collect::<Vec<_>>()
        });
        println!("{}", per_variant.report(Some((nvariants * 16) as f64)));
        report.case(&per_variant, Some((nvariants * 16) as f64));
        let batched = time(&format!("batched analyze_many, {nvariants} variants x 16"), 1, iters, || {
            analyzer.analyze_many(&jobs)
        });
        println!("{}", batched.report(Some((nvariants * 16) as f64)));
        report.case(&batched, Some((nvariants * 16) as f64));

        // Differential sanity: batched output must match per-variant.
        let solo: Vec<_> = jobs
            .iter()
            .map(|(label, ms, seed)| analyzer.analyze(label, ms, *seed).expect("analyze"))
            .collect();
        let many: Vec<_> = analyzer
            .analyze_many(&jobs)
            .into_iter()
            .map(|r| r.expect("batched analyze"))
            .collect();
        assert_eq!(solo.len(), many.len());
        for (a, b) in solo.iter().zip(&many) {
            assert_eq!(a.verdicts.len(), b.verdicts.len(), "{}", a.label);
            for (x, y) in a.verdicts.iter().zip(&b.verdicts) {
                assert_eq!(x.output, y.output, "{}/{}", a.label, x.name);
            }
        }

        let speedup = per_variant.median_s / batched.median_s;
        println!("batched analysis speedup ({nvariants} variants): {speedup:.2}x");
        report.metric("batched_analysis_speedup", speedup);
        report.metric("batched_analysis_variants", nvariants as f64);
    }

    match Manifest::load(&elastibench::artifacts_dir()) {
        Ok(manifest) => {
            for m in [8usize, 32, 128] {
                let info = manifest
                    .artifacts
                    .iter()
                    .find(|a| a.m == m && a.n == N && a.b == B)
                    .expect("artifact variant");
                let engine = AnalysisEngine::load(&manifest.path_of(info), info.m, info.b, info.n)
                    .expect("compile artifact");
                let (v1, v2, n_valid, idx) = inputs(m);
                let stats = time(&format!("xla artifact,     batch m={m}"), 1, if smoke { 3 } else { 7 }, || {
                    engine.analyze(&v1, &v2, &n_valid, &idx).expect("analyze")
                });
                println!("{}", stats.report(Some(m as f64)));
                report.case(&stats, Some(m as f64));
            }
        }
        Err(e) => println!("(skipping XLA engine: {e:#} — run `make artifacts`)"),
    }

    println!(
        "\nnote: interpret-mode Pallas lowers to plain HLO, so the XLA path here measures\n\
         the XLA:CPU-compiled kernel; real-TPU numbers are estimated from the VMEM/roofline\n\
         analysis in docs/perf.md."
    );

    if let Some(path) = json_path {
        let path = std::path::PathBuf::from(path);
        report.write(&path).expect("write bench report");
        println!("wrote {}", path.display());
    }
}
