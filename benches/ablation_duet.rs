//! Ablation: duet benchmarking (both versions in the same function
//! instance, paper §4) vs split execution (each version measured on its
//! own instances).
//!
//! The paper argues the duet design is what makes FaaS noise tolerable:
//! the instance/diurnal/co-tenancy factor multiplies both versions of a
//! pair equally and cancels in the relative difference. Splitting the
//! versions across instances re-exposes the full platform variance and
//! should produce false positives in an A/A setting and wider CIs.
//!
//! Run: `cargo bench --bench ablation_duet`

use elastibench::config::{ExperimentConfig, PlatformConfig};
use elastibench::coordinator::run_experiment;
use elastibench::exp::Workbench;
use elastibench::stats::{Analyzer, Measurements};
use elastibench::sut::Version;

fn main() {
    let wb = Workbench::native();
    let exp = ExperimentConfig {
        label: "ablation-duet".into(),
        seed: 0xD0E7,
        ..ExperimentConfig::default()
    };
    // Inflate platform noise slightly above default to make the contrast
    // visible at A/A (the paper's §3.1 "up to 15%" regime).
    let platform = PlatformConfig {
        instance_sigma: 0.05,
        diurnal_amplitude: 0.08,
        ..PlatformConfig::default()
    };

    // Duet A/A: one call measures both slots on the same instance.
    let duet = run_experiment(&wb.suite, &wb.sut, &platform, &exp, (Version::V1, Version::V1));

    // Split A/A: two independent runs; version samples come from
    // different instances at different times.
    let mut exp_a = exp.clone();
    exp_a.seed = 0xD0E7_0001;
    let run_a = run_experiment(&wb.suite, &wb.sut, &platform, &exp_a, (Version::V1, Version::V1));
    let mut exp_b = exp.clone();
    exp_b.seed = 0xD0E7_0002;
    exp_b.start_hour_utc += 3.0; // split runs happen at different times
    let run_b = run_experiment(&wb.suite, &wb.sut, &platform, &exp_b, (Version::V1, Version::V1));
    let split: Vec<Measurements> = run_a
        .measurements
        .iter()
        .zip(&run_b.measurements)
        .map(|(a, b)| Measurements {
            name: a.name.clone(),
            v1: a.v1.clone(),
            v2: b.v1.clone(),
        })
        .collect();

    let analyzer = Analyzer::native();
    let duet_analysis = analyzer
        .analyze("duet-aa", &duet.measurements, 7)
        .expect("analyze duet");
    let split_analysis = analyzer.analyze("split-aa", &split, 7).expect("analyze split");

    let duet_fp = duet_analysis.change_count();
    let split_fp = split_analysis.change_count();
    let mean_ci = |a: &elastibench::stats::SuiteAnalysis| {
        a.verdicts
            .iter()
            .map(|v| v.output.ci_size_pct() as f64)
            .sum::<f64>()
            / a.verdicts.len().max(1) as f64
    };

    println!("Ablation — duet vs split-instance benchmarking (A/A, inflated noise)\n");
    println!("| mode | analyzed | false positives | mean CI width |");
    println!("|---|---:|---:|---:|");
    println!(
        "| duet (paper design) | {} | {} | {:.2}% |",
        duet_analysis.verdicts.len(),
        duet_fp,
        mean_ci(&duet_analysis)
    );
    println!(
        "| split instances | {} | {} | {:.2}% |",
        split_analysis.verdicts.len(),
        split_fp,
        mean_ci(&split_analysis)
    );
    println!(
        "\nduet cancels the shared environment factor; split execution re-exposes it \
         (diurnal drift between runs + instance heterogeneity)."
    );
    assert!(duet_fp <= split_fp, "duet must not be worse than split");
    assert!(
        mean_ci(&duet_analysis) <= mean_ci(&split_analysis),
        "duet CIs must not be wider"
    );
}
