//! Regenerates paper Fig. 7: repetitions necessary until ElastiBench's CI
//! is no wider than the original dataset's (§6.2.7).
//!
//! This is the analysis-heavy target (42 prefix analyses x ~100
//! benchmarks x 2048 bootstrap resamples); pass `-- --backend xla` to run
//! it through the AOT artifact instead of the native engine.
//!
//! Run: `cargo bench --bench fig7_repeats [-- --backend xla]`

use elastibench::exp::sweep::repeats_sweep;
use elastibench::exp::{vm_original, Workbench};
use elastibench::report::render_curve;
use elastibench::stats::Analyzer;
use elastibench::util::benchkit::time;

fn main() {
    let use_xla = {
        let args: Vec<String> = std::env::args().collect();
        args.windows(2)
            .any(|w| w[0] == "--backend" && w[1] == "xla")
    };
    let mut wb = Workbench::native();
    if use_xla {
        wb.analyzer = Analyzer::xla(&elastibench::artifacts_dir())
            .expect("XLA backend needs `make artifacts`");
        println!("backend: XLA artifact");
    } else {
        println!("backend: native");
    }

    let original = vm_original(&wb).expect("vm baseline");
    let stats = time(
        "fig7: repeats sweep (135 results, 42 prefix analyses)",
        0,
        1,
        || repeats_sweep(&wb, &original.analysis).expect("sweep"),
    );
    println!("{}", stats.report(None));

    let sweep = repeats_sweep(&wb, &original.analysis).expect("sweep");
    println!("\nFig. 7 — % of benchmarks with CI size <= original, by repetitions");
    print!(
        "{}",
        render_curve(&sweep.curve, 64, 16, "results per benchmark")
    );
    println!(
        "\nparity at 45 results: {:.2}% (paper 75.95%) | at full {} results: {:.2}% (paper 89.87%)",
        sweep.pct_at_45,
        sweep.curve.last().map(|&(k, _)| k).unwrap_or(0),
        sweep.pct_at_full,
    );
    let overlapping = sweep
        .per_benchmark
        .iter()
        .filter(|b| b.overlaps_original)
        .count();
    println!(
        "benchmarks with overlapping final CIs: {}/{}",
        overlapping,
        sweep.per_benchmark.len()
    );
    assert!(
        sweep.pct_at_full >= sweep.pct_at_45,
        "curve must not decrease"
    );
}
