//! Regenerates paper Fig. 5: CDF of performance differences in the
//! baseline experiment (§6.2.2), plus the agreement/coverage numbers
//! against the VM original dataset. `-- --replication` runs the §6.2.3
//! replication instead.
//!
//! Run: `cargo bench --bench fig5_baseline`

use elastibench::exp::{baseline, replication, vm_original, Workbench};
use elastibench::report::render_cdf;
use elastibench::stats::{agreement, coverage};
use elastibench::util::benchkit::time;
use elastibench::util::stats::percentile_sorted;

fn main() {
    let replication_mode = std::env::args().any(|a| a == "--replication");
    let wb = Workbench::native();

    let stats = time("fig5: baseline experiment (simulate + analyze)", 0, 3, || {
        baseline(&wb).expect("baseline")
    });
    println!("{}", stats.report(None));

    let result = if replication_mode {
        replication(&wb).expect("replication")
    } else {
        baseline(&wb).expect("baseline")
    };
    let original = vm_original(&wb).expect("vm baseline");

    let mut diffs = result.analysis.abs_diffs_pct();
    diffs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "\nFig. 5 — CDF of |performance difference| in the {} experiment",
        result.analysis.label
    );
    print!("{}", render_cdf(&diffs, 64, 16, "|diff| [%]"));

    let mut change_mags: Vec<f64> = result
        .analysis
        .verdicts
        .iter()
        .filter(|v| v.change.is_change())
        .map(|v| v.output.boot_median_pct.abs() as f64)
        .collect();
    change_mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "\nchanges {} | median change {:.2}% (paper 3.08–4.71%) | max change {:.0}% (paper 116%)",
        change_mags.len(),
        if change_mags.is_empty() {
            0.0
        } else {
            percentile_sorted(&change_mags, 50.0)
        },
        change_mags.last().copied().unwrap_or(0.0),
    );

    let rep = agreement(&result.analysis, &original.analysis);
    let cov = coverage(&result.analysis, &original.analysis);
    println!(
        "agreement with original: {:.2}% over {} common (paper 95.65% over 91)",
        rep.agreement_pct(),
        rep.common
    );
    for d in &rep.disagreements {
        println!("  {:?} {} ({:.2}%)", d.kind, d.name, d.max_abs_diff_pct);
    }
    println!(
        "coverage one-sided {:.2}% / {:.2}% (paper 86.96% / 52.17%), two-sided {:.2}% (paper 50%)",
        cov.one_sided_a_in_b_pct, cov.one_sided_b_in_a_pct, cov.two_sided_pct
    );
    println!(
        "duration {:.1} min (paper ~11 min) | cost ${:.2} (paper $0.18–1.18)",
        result.report.wall_s / 60.0,
        result.report.cost_usd
    );
    assert!(rep.agreement_pct() > 85.0, "agreement shape must hold");
}
