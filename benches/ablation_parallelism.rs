//! Ablation of §4's parallelism claim: "higher parallelism leads to
//! shorter runs, while increasing cost due to the increased number of
//! cold starts."
//!
//! Sweeps the runner's call parallelism and reports duration, cost and
//! cold starts for the baseline configuration.
//!
//! Run: `cargo bench --bench ablation_parallelism`

use elastibench::config::ExperimentConfig;
use elastibench::coordinator::run_experiment;
use elastibench::exp::Workbench;
use elastibench::sut::Version;

fn main() {
    let wb = Workbench::native();
    println!("Parallelism sweep — baseline configuration (106 benchmarks x 15 calls)\n");
    println!("| parallelism | invoke duration | total duration | cost | cold starts | instances |");
    println!("|---:|---:|---:|---:|---:|---:|");


    let mut results = Vec::new();
    for parallelism in [10usize, 50, 150, 300, 600] {
        let exp = ExperimentConfig {
            label: format!("par-{parallelism}"),
            parallelism,
            seed: 0xAB1A,
            ..ExperimentConfig::default()
        };
        let report = run_experiment(&wb.suite, &wb.sut, &wb.platform, &exp, (Version::V1, Version::V2));
        println!(
            "| {} | {:.1} min | {:.1} min | ${:.2} | {} | {} |",
            parallelism,
            report.invoke_wall_s / 60.0,
            report.wall_s / 60.0,
            report.cost_usd,
            report.platform.cold_starts,
            report.platform.instances_created,
        );
        results.push((parallelism, report));
    }

    // Shape assertions: duration monotone down, cold starts monotone up.
    for w in results.windows(2) {
        let (p0, r0) = &w[0];
        let (p1, r1) = &w[1];
        assert!(
            r1.invoke_wall_s <= r0.invoke_wall_s * 1.05,
            "parallelism {p1} should not be slower than {p0}"
        );
        assert!(
            r1.platform.cold_starts >= r0.platform.cold_starts,
            "parallelism {p1} should not cold-start less than {p0}"
        );

    }
    println!(
        "\nhigher parallelism shortens the run and adds cold starts — the §4 trade-off."
    );
}
