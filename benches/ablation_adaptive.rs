//! Ablation of the §7.2 benchmarking strategy: adaptive stopping (stop a
//! benchmark once its 99% CI is below a width target) vs the paper's
//! fixed 45-results budget.
//!
//! Reports per-benchmark stopping points, the saved fraction of calls,
//! and verifies the adaptive verdicts still agree with the fixed ones.
//!
//! Run: `cargo bench --bench ablation_adaptive`

use elastibench::exp::{baseline, Workbench};
use elastibench::stats::{adaptive_plan, agreement, Analyzer, Measurements, StoppingRule};

fn main() {
    let wb = Workbench::native();
    let base = baseline(&wb).expect("baseline");
    let analyzer = Analyzer::native();
    let rule = StoppingRule::default();

    let plan = adaptive_plan(&analyzer, &base.report.measurements, &rule, 0xADA7)
        .expect("adaptive plan");

    // Re-analyze with the adaptive budgets and compare verdicts.
    let truncated: Vec<Measurements> = base
        .report
        .measurements
        .iter()
        .filter_map(|m| {
            let (_, needed) = plan.per_benchmark.iter().find(|(n, _)| n == &m.name)?;
            Some(Measurements {
                name: m.name.clone(),
                v1: m.v1.iter().copied().take(*needed).collect(),
                v2: m.v2.iter().copied().take(*needed).collect(),
            })
        })
        .collect();
    let adaptive_analysis = analyzer
        .analyze("adaptive", &truncated, 0xBA5E ^ 0xA11A)
        .expect("adaptive analysis");
    let rep = agreement(&adaptive_analysis, &base.analysis);

    let mut hist = [0usize; 4]; // <=21, <=30, <=39, 40+
    for (_, needed) in &plan.per_benchmark {
        let bucket = match needed {
            0..=21 => 0,
            22..=30 => 1,
            31..=39 => 2,
            _ => 3,
        };
        hist[bucket] += 1;
    }

    println!("Adaptive stopping (target CI width {:.1} pp) vs fixed 45 results\n", rule.target_ci_pct);
    println!("| stopping point | benchmarks |");
    println!("|---|---:|");
    println!("| <=21 results | {} |", hist[0]);
    println!("| 22-30 results | {} |", hist[1]);
    println!("| 31-39 results | {} |", hist[2]);
    println!("| full 40-45 results | {} |", hist[3]);
    println!(
        "\nresults collected: {} adaptive vs {} fixed — {:.1}% of calls (≈cost) saved",
        plan.adaptive_total,
        plan.fixed_total,
        plan.saved_pct()
    );
    println!(
        "verdict agreement with the fixed strategy: {:.2}% over {} benchmarks",
        rep.agreement_pct(),
        rep.common
    );
    assert!(plan.saved_pct() > 0.0, "adaptive must save something");
    assert!(
        rep.agreement_pct() >= 90.0,
        "adaptive stopping must not change verdicts materially: {:.2}%",
        rep.agreement_pct()
    );
}
