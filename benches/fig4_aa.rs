//! Regenerates paper Fig. 4: CDF of performance differences in the A/A
//! experiment (§6.2.1). Shape targets: 0 detected changes, ~90 of 106
//! benchmarks executed, small median |diff| with a heavy max tail.
//!
//! Run: `cargo bench --bench fig4_aa`

use elastibench::exp::{aa, Workbench};
use elastibench::report::render_cdf;
use elastibench::util::benchkit::time;
use elastibench::util::stats::percentile_sorted;

fn main() {
    let wb = Workbench::native();
    let stats = time("fig4: A/A experiment (simulate + analyze)", 0, 3, || {
        aa(&wb).expect("aa experiment")
    });
    println!("{}", stats.report(None));

    let result = aa(&wb).expect("aa experiment");
    let mut diffs = result.analysis.abs_diffs_pct();
    diffs.sort_by(|a, b| a.partial_cmp(b).unwrap());

    println!("\nFig. 4 — CDF of |performance difference| in the A/A experiment");
    print!("{}", render_cdf(&diffs, 64, 16, "|diff| [%]"));
    println!(
        "\nexecuted {}/{} | changes detected {} (paper: 0) | median {:.3}% (paper 0.047%) \
         | max {:.1}% (paper 32%)",
        result.analysis.verdicts.len(),
        wb.suite.len(),
        result.analysis.change_count(),
        percentile_sorted(&diffs, 50.0),
        diffs.last().copied().unwrap_or(0.0),
    );
    println!(
        "duration {:.1} min (paper ~8 min) | cost ${:.2} (paper $1.18)",
        result.report.wall_s / 60.0,
        result.report.cost_usd
    );
    assert_eq!(result.analysis.change_count(), 0, "A/A must detect nothing");
}
