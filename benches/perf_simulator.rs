//! §Perf: simulator-side throughput — DES engine event rate and
//! end-to-end experiment simulation wallclock (the L3 hot paths), plus
//! the pooled-vs-reference instance-scheduler comparison at large fleet
//! sizes. Numbers are logged in `docs/perf.md`.
//!
//! Run: `cargo bench --bench perf_simulator`
//!
//! Flags (after `--`):
//!
//! * `--smoke`        shortened CI variant (fewer iterations, smaller
//!                    workload, same shapes);
//! * `--json PATH`    additionally emit a machine-readable
//!                    `elastibench.bench-report.v1` document (CI writes
//!                    `BENCH_simulator.json`; format in
//!                    `docs/benchmarks.md`).

use elastibench::config::{ExperimentConfig, PlatformConfig, SutConfig};
use elastibench::coordinator::{
    run_experiment, run_experiment_observed, run_experiment_reference, strategy_by_name,
};
use elastibench::des::Sim;
use elastibench::exp::{baseline, Workbench};
use elastibench::sut::{generate, Version};
use elastibench::telemetry::{NullSink, SharedSink};
use elastibench::util::benchkit::{time, BenchReport};
use std::cell::RefCell;
use std::rc::Rc;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .map(|i| args.get(i + 1).expect("--json needs a PATH").clone());
    let mut report = BenchReport::new("simulator");

    // Raw DES engine: schedule/pop churn with a live heap.
    let events = if smoke { 50_000usize } else { 200_000 };
    let stats = time(&format!("des: {events} chained events"), 1, if smoke { 3 } else { 7 }, || {
        let mut sim: Sim<u64> = Sim::new();
        for i in 0..64 {
            sim.schedule(1.0 + i as f64, i);
        }
        let mut fired = 0u64;
        sim.run(|sim, _, e| {
            fired += 1;
            if (fired as usize) < events {
                sim.schedule(1.0 + (e % 7) as f64, e + 1);
            }
        });
        fired
    });
    println!("{}", stats.report(Some(events as f64)));
    report.metric("des_events_per_s", events as f64 / stats.median_s);
    report.case(&stats, Some(events as f64));

    // DES with fat payloads and a deep heap: the arena keeps sift swaps
    // on 24-byte keys even when events carry duet-pair vectors.
    let pending = if smoke { 2_000usize } else { 10_000 };
    let churn = if smoke { 50_000usize } else { 200_000 };
    let stats = time(
        &format!("des: {churn} fat events, {pending} pending"),
        1,
        if smoke { 3 } else { 7 },
        || {
            let mut sim: Sim<Vec<(f64, f64)>> = Sim::new();
            for i in 0..pending {
                sim.schedule(1.0 + (i % 97) as f64, vec![(i as f64, i as f64); 3]);
            }
            let mut fired = 0usize;
            let mut acc = 0.0f64;
            sim.run(|sim, _, payload| {
                fired += 1;
                acc += payload[0].0;
                if fired + sim.pending() < churn {
                    sim.schedule(1.0 + (fired % 13) as f64, payload);
                }
            });
            acc
        },
    );
    println!("{}", stats.report(Some(churn as f64)));
    report.case(&stats, Some(churn as f64));

    // Large-fleet experiment: the full coordinator + platform + benchexec
    // path at parallelism >= 1000, pooled (slot map + idle deque) vs the
    // retired O(N)-scan reference pool. Identical seeds and coordinator
    // code; the wallclock delta is the scheduler's alone. Default 600 s
    // keepalive: no mid-flight reaping, so the reference stays on the
    // domain where it is correct and both runs produce identical reports.
    let sut = SutConfig {
        benchmark_count: if smoke { 120 } else { 200 },
        true_changes: 20,
        faas_incompatible: 5,
        slow_setup: 3,
        ..SutConfig::default()
    };
    let suite = generate(&sut);
    let platform = PlatformConfig {
        concurrency_limit: 4000,
        ..PlatformConfig::default()
    };
    let exp = ExperimentConfig {
        label: "hyperscale-bench".into(),
        repeats_per_call: 1,
        calls_per_benchmark: if smoke { 15 } else { 25 },
        parallelism: if smoke { 1000 } else { 2000 },
        ..ExperimentConfig::default()
    };
    let calls = suite.len() * exp.calls_per_benchmark;
    let iters = if smoke { 2 } else { 5 };
    let pooled = time(
        &format!("pooled pool: {calls} calls, parallelism {}", exp.parallelism),
        1,
        iters,
        || run_experiment(&suite, &sut, &platform, &exp, (Version::V1, Version::V2)),
    );
    println!("{}", pooled.report(Some(calls as f64)));
    report.case(&pooled, Some(calls as f64));
    let reference = time(
        &format!("reference pool: {calls} calls, parallelism {}", exp.parallelism),
        1,
        iters,
        || run_experiment_reference(&suite, &sut, &platform, &exp, (Version::V1, Version::V2)),
    );
    println!("{}", reference.report(Some(calls as f64)));
    report.case(&reference, Some(calls as f64));
    let speedup = reference.median_s / pooled.median_s;
    println!(
        "full-experiment speedup (reference / pooled) at parallelism {}: {speedup:.1}x",
        exp.parallelism
    );
    report.metric("full_experiment_speedup", speedup);
    report.metric("full_experiment_parallelism", exp.parallelism as f64);
    report.metric("experiment_wall_s", pooled.median_s);
    report.metric("experiment_calls_per_s", calls as f64 / pooled.median_s);

    // Same hyperscale workload with a NullSink attached: the telemetry
    // hooks sit on the platform/coordinator hot paths, so this pins
    // their cost when nobody is listening. Expected to be noise-level.
    let duet = strategy_by_name("duet").expect("duet strategy");
    let observed = time(
        &format!(
            "pooled pool + NullSink: {calls} calls, parallelism {}",
            exp.parallelism
        ),
        1,
        iters,
        || {
            let sink: SharedSink = Rc::new(RefCell::new(NullSink));
            run_experiment_observed(
                &suite,
                &sut,
                &platform,
                &exp,
                (Version::V1, Version::V2),
                duet,
                None,
                &sink,
            )
        },
    );
    println!("{}", observed.report(Some(calls as f64)));
    report.case(&observed, Some(calls as f64));
    let overhead_pct = (observed.median_s / pooled.median_s - 1.0) * 100.0;
    println!("sink overhead (NullSink vs untraced, same workload): {overhead_pct:+.1}%");
    report.metric("sink_overhead_pct", overhead_pct);

    // Full experiment simulation (106 benchmarks x 15 calls, parallelism
    // 150) WITHOUT analysis — the paper-scale coordinator path.
    let sut = SutConfig::default();
    let suite = generate(&sut);
    let platform = PlatformConfig::default();
    let exp = ExperimentConfig::default();
    let stats = time("coordinator: full baseline experiment (no analysis)", 1, if smoke { 2 } else { 5 }, || {
        run_experiment(&suite, &sut, &platform, &exp, (Version::V1, Version::V2))
    });
    let calls = suite.len() * exp.calls_per_benchmark;
    println!("{}", stats.report(Some(calls as f64)));
    report.case(&stats, Some(calls as f64));
    report.metric("baseline_experiment_wall_s", stats.median_s);

    // Experiment + native analysis (the `elastibench run` path).
    let wb = Workbench::native();
    let stats = time("end-to-end: baseline experiment + native analysis", 1, if smoke { 2 } else { 5 }, || {
        baseline(&wb).expect("baseline")
    });
    println!("{}", stats.report(None));
    report.case(&stats, None);

    if let Some(path) = json_path {
        let path = std::path::PathBuf::from(path);
        report.write(&path).expect("write bench report");
        println!("wrote {}", path.display());
    }
}
