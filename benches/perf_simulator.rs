//! §Perf: simulator-side throughput — DES engine event rate and
//! end-to-end experiment simulation wallclock (the L3 hot paths).
//!
//! Run: `cargo bench --bench perf_simulator`

use elastibench::config::{ExperimentConfig, PlatformConfig, SutConfig};
use elastibench::coordinator::run_experiment;
use elastibench::des::Sim;
use elastibench::exp::{baseline, Workbench};
use elastibench::sut::{generate, Version};
use elastibench::util::benchkit::time;

fn main() {
    // Raw DES engine: schedule/pop churn with a live heap.
    let events = 200_000usize;
    let stats = time(&format!("des: {events} chained events"), 1, 7, || {
        let mut sim: Sim<u64> = Sim::new();
        for i in 0..64 {
            sim.schedule(1.0 + i as f64, i);
        }
        let mut fired = 0u64;
        sim.run(|sim, _, e| {
            fired += 1;
            if (fired as usize) < events {
                sim.schedule(1.0 + (e % 7) as f64, e + 1);
            }
        });
        fired
    });
    println!("{}", stats.report(Some(events as f64)));

    // Full experiment simulation (106 benchmarks x 15 calls, parallelism
    // 150) WITHOUT analysis — the coordinator + platform + benchexec path.
    let sut = SutConfig::default();
    let suite = generate(&sut);
    let platform = PlatformConfig::default();
    let exp = ExperimentConfig::default();
    let stats = time("coordinator: full baseline experiment (no analysis)", 1, 5, || {
        run_experiment(&suite, &sut, &platform, &exp, (Version::V1, Version::V2))
    });
    let calls = suite.len() * exp.calls_per_benchmark;
    println!("{}", stats.report(Some(calls as f64)));

    // Experiment + native analysis (the `elastibench run` path).
    let wb = Workbench::native();
    let stats = time("end-to-end: baseline experiment + native analysis", 1, 5, || {
        baseline(&wb).expect("baseline")
    });
    println!("{}", stats.report(None));
}
