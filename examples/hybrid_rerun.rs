//! §7.4 hybrid execution: re-run restricted-environment failures on a
//! fallback VM so the WHOLE suite gets verdicts, "without significantly
//! increasing cost and duration".
//!
//! ```bash
//! cargo run --release --example hybrid_rerun
//! ```

use elastibench::config::{ExperimentConfig, VmConfig};
use elastibench::coordinator::{run_experiment, run_hybrid};
use elastibench::exp::Workbench;
use elastibench::stats::Analyzer;
use elastibench::sut::Version;

fn main() -> anyhow::Result<()> {
    let wb = Workbench::native();
    let exp = ExperimentConfig::default();
    let vm = VmConfig::default();

    let faas_only = run_experiment(&wb.suite, &wb.sut, &wb.platform, &exp, (Version::V1, Version::V2));
    let hybrid = run_hybrid(&wb.suite, &wb.sut, &wb.platform, &exp, &vm);

    let analyzer = Analyzer::native();
    let faas_analysis = analyzer.analyze("faas-only", &faas_only.measurements, exp.seed)?;
    let hybrid_analysis = analyzer.analyze("hybrid", &hybrid.measurements, exp.seed)?;

    println!("| strategy | verdicts | coverage | duration | cost |");
    println!("|---|---:|---:|---:|---:|");
    println!(
        "| FaaS only | {} | {:.0}% | {:.1} min | ${:.2} |",
        faas_analysis.verdicts.len(),
        faas_analysis.verdicts.len() as f64 / wb.suite.len() as f64 * 100.0,
        faas_only.wall_s / 60.0,
        faas_only.cost_usd
    );
    println!(
        "| hybrid (§7.4) | {} | {:.0}% | {:.1} min | ${:.2} |",
        hybrid_analysis.verdicts.len(),
        hybrid_analysis.verdicts.len() as f64 / wb.suite.len() as f64 * 100.0,
        hybrid.total_wall_s() / 60.0,
        hybrid.total_cost_usd()
    );
    println!("\nfallback benchmarks ({}):", hybrid.fallback_benchmarks.len());
    for name in &hybrid.fallback_benchmarks {
        let verdict = hybrid_analysis
            .get(name)
            .map(|v| format!("{:?} [{:+.2}%, {:+.2}%]", v.change, v.output.ci_lo_pct, v.output.ci_hi_pct))
            .unwrap_or_else(|| "still unmeasured".into());
        println!("  {name:<44} {verdict}");
    }
    println!(
        "\nhybrid adds {:.0} s wall and ${:.2} over FaaS-only for {} extra verdicts \
         — the paper's §7.4 trade-off.",
        hybrid.total_wall_s() - faas_only.wall_s,
        hybrid.total_cost_usd() - faas_only.cost_usd,
        hybrid_analysis.verdicts.len() - faas_analysis.verdicts.len()
    );
    Ok(())
}
