//! CI/CD gate: the paper's §1 motivating use case — run the
//! microbenchmark suite on every change and fail the pipeline when a
//! performance regression is detected.
//!
//! ```bash
//! cargo run --release --example cicd_gate            # v2 has regressions
//! cargo run --release --example cicd_gate -- --clean # A/A: must pass
//! ```
//!
//! The gate is a catalog scenario (`quick-smoke`, the same recipe the CI
//! workflow smoke-tests) flipped to A/A mode by `--clean` — no hand
//! wiring. Exit code 0 = gate passed, 1 = regression(s) detected; wire
//! it into a pipeline exactly like a test step. Only regressions above a
//! noise margin (3%, cf. §2 [20, 43]) fail the gate; improvements are
//! reported but do not block.

use elastibench::scenario::{catalog_entry, run_scenario, DuetMode};
use elastibench::stats::{Analyzer, ChangeKind};

/// Regressions below this are within cloud-noise territory (§2).
const GATE_MARGIN_PCT: f32 = 3.0;

fn main() {
    let clean = std::env::args().any(|a| a == "--clean");
    let mut sc = catalog_entry("quick-smoke").expect("catalog entry");
    if clean {
        println!("gate: comparing identical versions (A/A)");
        sc.mode = DuetMode::Aa;
    } else {
        println!("gate: comparing v1 (main) vs v2 (candidate)");
    }

    let result = run_scenario(&sc, &Analyzer::native()).expect("scenario run");
    println!(
        "suite finished in {:.1} min at ${:.2} — fast enough to gate every merge (paper §1)\n",
        result.run.wall_s / 60.0,
        result.run.cost_usd
    );

    let mut regressions = Vec::new();
    let mut improvements = Vec::new();
    for v in &result.analysis.verdicts {
        match v.change {
            ChangeKind::Regression if v.output.ci_lo_pct >= GATE_MARGIN_PCT => {
                regressions.push(v)
            }
            ChangeKind::Regression => { /* below margin: noise territory */ }
            ChangeKind::Improvement => improvements.push(v),
            ChangeKind::NoChange => {}
        }
    }

    for v in &improvements {
        println!(
            "  IMPROVED  {:<40} {:+.2}% [{:+.2}%, {:+.2}%]",
            v.name, v.output.boot_median_pct, v.output.ci_lo_pct, v.output.ci_hi_pct
        );
    }
    for v in &regressions {
        println!(
            "  REGRESSED {:<40} {:+.2}% [{:+.2}%, {:+.2}%]",
            v.name, v.output.boot_median_pct, v.output.ci_lo_pct, v.output.ci_hi_pct
        );
    }

    if regressions.is_empty() {
        println!(
            "\ngate PASSED ({} benchmarks checked)",
            result.analysis.verdicts.len()
        );
        std::process::exit(0);
    } else {
        println!(
            "\ngate FAILED: {} regression(s) above the {GATE_MARGIN_PCT}% margin",
            regressions.len()
        );
        std::process::exit(1);
    }
}
