//! CI/CD gate, the paper's §1 motivating use case — now as the full
//! *continuous* loop: run the suite on every change, record the result
//! in the history store, and gate the newest run against the recorded
//! baseline of prior runs.
//!
//! ```bash
//! cargo run --release --example cicd_gate            # candidate regresses
//! cargo run --release --example cicd_gate -- --clean # clean candidate: passes
//! ```
//!
//! The example builds a fresh store, simulates three "main" builds
//! (A/A runs over different experiment seeds — the false-positive
//! control, so the baseline is honest history, not copies of one run),
//! then runs the candidate: by default a v1-vs-v2 run whose injected
//! true changes play the regression; with `--clean` another A/A run.
//! The candidate is recorded and `history::evaluate` decides. Exit code
//! 0 = gate passed, 1 = cross-run regression(s) — wire it into a
//! pipeline exactly like a test step.
//!
//! Everything is deterministic: commit ids are strings, timestamps are
//! build numbers, seeds are pinned — rerunning the example reproduces
//! the same gate table byte for byte.

use elastibench::history::{evaluate, GatePolicy, HistoryStore, Timeline};
use elastibench::report::gate_table;
use elastibench::scenario::{catalog_entry, run_scenario, DuetMode, Scenario};
use elastibench::stats::Analyzer;

fn run_build(sc: &Scenario, commit: &str, store: &HistoryStore, build: usize) {
    let mut report = run_scenario(sc, &Analyzer::native()).expect("scenario run");
    report.commit = commit.to_string();
    let meta = store
        .record(&report, &format!("build-{build}"))
        .expect("record run");
    println!(
        "  recorded {commit:<10} as {} ({} analyzed, {} regression verdict(s), {:.1} min, ${:.2})",
        meta.run_id, meta.analyzed, meta.regressions, meta.wall_s / 60.0, meta.cost_usd
    );
}

fn main() {
    let clean = std::env::args().any(|a| a == "--clean");
    let store_dir = std::env::temp_dir().join("elastibench_cicd_gate_store");
    let _ = std::fs::remove_dir_all(&store_dir);
    let store = HistoryStore::open(&store_dir);

    let base = catalog_entry("quick-smoke").expect("catalog entry");

    // Three "main" builds: A/A runs (both duet slots run v1) over
    // different experiment seeds — genuine run-to-run noise, no true
    // changes. This is the recorded baseline history.
    println!("building baseline history ({} benchmarks on {}):", base.sut.benchmark_count, base.profile_name);
    for (i, commit) in ["main-1", "main-2", "main-3"].iter().enumerate() {
        let mut sc = base.clone();
        sc.mode = DuetMode::Aa;
        sc.exp.seed = base.exp.seed + i as u64;
        run_build(&sc, commit, &store, i + 1);
    }

    // The candidate build: v1 vs v2 flips the recipe's injected true
    // changes live (the "regression"); --clean stays A/A.
    let mut candidate = base.clone();
    candidate.exp.seed = base.exp.seed + 3;
    if clean {
        println!("\ncandidate: clean change (A/A — no real regressions)");
        candidate.mode = DuetMode::Aa;
    } else {
        println!("\ncandidate: v1 vs v2 (the recipe's true changes now bite)");
        candidate.mode = DuetMode::Ab;
    }
    run_build(&candidate, "candidate", &store, 4);

    // Gate the newest recorded run against the prior runs.
    let tl = Timeline::load(&store, &base.name).expect("timeline");
    let policy = GatePolicy::default();
    let outcome = evaluate(&tl, &policy).expect("gate");
    println!(
        "\ngating {} (commit {}) against [{}], window {}, threshold {}%",
        outcome.newest_run,
        outcome.newest_commit,
        outcome.baseline_runs.join(", "),
        policy.window,
        policy.threshold_pct
    );

    let _ = std::fs::remove_dir_all(&store_dir);
    if outcome.passed() {
        println!(
            "\ngate PASSED ({} benchmark(s) checked against history)",
            outcome.checked
        );
        std::process::exit(0);
    }
    println!();
    print!("{}", gate_table(&outcome.table_rows()));
    println!(
        "\ngate FAILED: {} benchmark(s) regressed vs recorded history",
        outcome.findings.len()
    );
    std::process::exit(1);
}
