//! End-to-end reproduction driver: runs the COMPLETE paper evaluation —
//! the VM original dataset, all five FaaS experiments, the Fig. 7 repeats
//! sweep, and every comparison — through all three layers (Rust
//! coordinator + DES substrates, with the bootstrap analysis executed by
//! the AOT-compiled XLA artifact when available).
//!
//! ```bash
//! make artifacts && cargo run --release --example full_reproduction
//! ```
//!
//! Prints the paper-vs-measured reproduction report and writes it to
//! `out/reproduction.md`.

use elastibench::exp::{reproduce_all, Workbench};
use elastibench::report::write_text;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    // Prefer the AOT artifact path (L1/L2 through PJRT); fall back to the
    // native engine with a notice so the driver also works pre-`make
    // artifacts`.
    let wb = match Workbench::xla() {
        Ok(wb) => {
            eprintln!("analysis backend: XLA artifact (artifacts/)");
            wb
        }
        Err(e) => {
            eprintln!("analysis backend: native (XLA unavailable: {e:#})");
            Workbench::native()
        }
    };

    let t0 = std::time::Instant::now();
    let report = reproduce_all(&wb)?;
    let host_s = t0.elapsed().as_secs_f64();

    print!("{report}");
    println!("\n(host wallclock for the full reproduction: {host_s:.1} s)");

    let out = Path::new("out/reproduction.md");
    write_text(out, &report)?;
    println!("wrote {}", out.display());
    Ok(())
}
