//! Quickstart: detect performance changes between two versions of a
//! (synthetic) SUT with ElastiBench in under a minute.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a small 20-benchmark suite, runs the paper's baseline
//! configuration against the simulated FaaS platform, analyzes the duet
//! measurements with 99% bootstrap CIs, and prints the verdicts next to
//! the generator's ground truth.

use elastibench::config::SutConfig;
use elastibench::exp::{baseline, Workbench};
use elastibench::stats::ChangeKind;

fn main() -> anyhow::Result<()> {
    // A small suite keeps the quickstart fast; the full paper suite is
    // SutConfig::default() (106 benchmarks).
    let wb = Workbench::with_sut(SutConfig {
        benchmark_count: 20,
        true_changes: 6,
        faas_incompatible: 2,
        slow_setup: 1,
        ..SutConfig::default()
    });

    let result = baseline(&wb)?;
    println!(
        "ran {} calls on the simulated platform in {:.1} min (cost ${:.2}, {} cold starts)\n",
        result.report.calls_total,
        result.report.wall_s / 60.0,
        result.report.cost_usd,
        result.report.platform.cold_starts
    );

    println!(
        "{:<44} {:>22} {:>10} {:>10}",
        "benchmark", "99% CI of median diff", "verdict", "truth"
    );
    let mut correct = 0usize;
    let mut total = 0usize;
    for v in &result.analysis.verdicts {
        let b = wb.suite.get(&v.name).expect("benchmark exists");
        let truth_pct = b.true_change_pct(true);
        let truth = if b.has_true_change() || b.benchmark_changed() {
            format!("{truth_pct:+.1}%")
        } else {
            "none".to_string()
        };
        let verdict = match v.change {
            ChangeKind::NoChange => "-".to_string(),
            ChangeKind::Regression => "SLOWER".to_string(),
            ChangeKind::Improvement => "faster".to_string(),
        };
        let detected_correctly = match v.change {
            ChangeKind::NoChange => truth_pct.abs() < 3.0,
            ChangeKind::Regression => truth_pct > 0.0,
            ChangeKind::Improvement => truth_pct < 0.0,
        };
        total += 1;
        correct += detected_correctly as usize;
        println!(
            "{:<44} [{:>+7.2}%, {:>+7.2}%] {:>10} {:>10}",
            v.name, v.output.ci_lo_pct, v.output.ci_hi_pct, verdict, truth
        );
    }
    for name in &result.analysis.excluded {
        println!("{name:<44} {:>22} {:>10}", "(too few results)", "n/a");
    }
    println!(
        "\n{}/{} verdicts consistent with ground truth \
         (missed truths are sub-threshold changes — cf. paper §2)",
        correct, total
    );
    Ok(())
}
