//! Quickstart: run a shipped scenario and compare its verdicts to the
//! generator's ground truth.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Runs the `quick-smoke` catalog entry (12 synthetic benchmarks on the
//! Lambda-shaped profile — the same recipe CI smoke-tests), prints the
//! 99% bootstrap CIs next to the known true effects, and shows where the
//! exported JSON report would land. The full catalog is
//! `elastibench scenario list`; the full guide is docs/benchmarks.md.

use elastibench::scenario::{catalog_entry, run_scenario};
use elastibench::stats::{Analyzer, ChangeKind};
use elastibench::sut::generate;

fn main() -> anyhow::Result<()> {
    let sc = catalog_entry("quick-smoke")?;
    println!(
        "scenario {} on profile {} ({} benchmarks, parallelism {})\n",
        sc.name, sc.profile_name, sc.sut.benchmark_count, sc.exp.parallelism
    );

    let result = run_scenario(&sc, &Analyzer::native())?;
    println!(
        "ran {} calls on the simulated platform in {:.1} min (cost ${:.2}, {} cold starts)\n",
        result.run.calls_total,
        result.run.wall_s / 60.0,
        result.run.cost_usd,
        result.run.platform.cold_starts
    );

    // The suite is regenerated from the recipe's pinned SUT seed, so the
    // ground truth here is exactly what the run measured against.
    let suite = generate(&sc.sut);
    println!(
        "{:<44} {:>22} {:>10} {:>10}",
        "benchmark", "99% CI of median diff", "verdict", "truth"
    );
    let mut correct = 0usize;
    let mut total = 0usize;
    for v in &result.analysis.verdicts {
        let b = suite.get(&v.name).expect("benchmark exists");
        let truth_pct = b.true_change_pct(true);
        let truth = if b.has_true_change() || b.benchmark_changed() {
            format!("{truth_pct:+.1}%")
        } else {
            "none".to_string()
        };
        let verdict = match v.change {
            ChangeKind::NoChange => "-",
            ChangeKind::Regression => "SLOWER",
            ChangeKind::Improvement => "faster",
        };
        let detected_correctly = match v.change {
            ChangeKind::NoChange => truth_pct.abs() < 3.0,
            ChangeKind::Regression => truth_pct > 0.0,
            ChangeKind::Improvement => truth_pct < 0.0,
        };
        total += 1;
        correct += detected_correctly as usize;
        println!(
            "{:<44} [{:>+7.2}%, {:>+7.2}%] {:>10} {:>10}",
            v.name, v.output.ci_lo_pct, v.output.ci_hi_pct, verdict, truth
        );
    }
    for name in &result.analysis.excluded {
        println!("{name:<44} {:>22} {:>10}", "(too few results)", "n/a");
    }
    println!(
        "\n{}/{} verdicts consistent with ground truth \
         (missed truths are sub-threshold changes — cf. paper §2)",
        correct, total
    );
    println!(
        "\nexport the same run as JSON: \
         elastibench scenario run {} --out-dir results/",
        sc.name
    );
    Ok(())
}
