//! Explore the §4 parallelism trade-off interactively: duration and cost
//! of a full suite run as a function of the runner's call parallelism.
//!
//! ```bash
//! cargo run --release --example parallelism_sweep -- 10 50 150 600
//! ```

use elastibench::config::ExperimentConfig;
use elastibench::coordinator::run_experiment;
use elastibench::exp::Workbench;
use elastibench::sut::Version;

fn main() {
    let mut levels: Vec<usize> = std::env::args()
        .skip(1)
        .filter_map(|a| a.parse().ok())
        .collect();
    if levels.is_empty() {
        levels = vec![1, 10, 50, 150, 300, 600];
    }

    let wb = Workbench::native();
    println!(
        "suite: {} benchmarks, {} calls each\n",
        wb.suite.len(),
        ExperimentConfig::default().calls_per_benchmark
    );
    println!(
        "{:>12} {:>15} {:>12} {:>12} {:>12}",
        "parallelism", "invoke wall", "cost", "cold starts", "$/minute saved"
    );

    let mut baseline_wall = None;
    let mut baseline_cost = None;
    for parallelism in levels {
        let exp = ExperimentConfig {
            label: format!("sweep-{parallelism}"),
            parallelism,
            seed: 0x5EED,
            ..ExperimentConfig::default()
        };
        let report =
            run_experiment(&wb.suite, &wb.sut, &wb.platform, &exp, (Version::V1, Version::V2));
        let (wall_min, cost) = (report.invoke_wall_s / 60.0, report.cost_usd);
        let marginal = match (baseline_wall, baseline_cost) {
            (Some(w0), Some(c0)) if w0 > wall_min && cost > c0 => {
                format!("{:.4}", (cost - c0) / (w0 - wall_min))
            }
            _ => "—".to_string(),
        };
        if baseline_wall.is_none() {
            baseline_wall = Some(wall_min);
            baseline_cost = Some(cost);
        }
        println!(
            "{parallelism:>12} {wall_min:>13.1}m {cost:>11.2}$ {:>12} {marginal:>12}",
            report.platform.cold_starts
        );
    }
    println!(
        "\nhigher parallelism buys wall-clock time with cold starts (paper §4); the\n\
         marginal column prices each saved minute relative to the first level."
    );
}
